//! Event-exact weight-stationary simulation.
//!
//! Streams actual quantized data through functional PEs tile by tile and
//! counts useful MACs per lane. Also *computes the GEMM result*, so every
//! simulation doubles as a numerical check against the reference matmul
//! (the dataflow must not just be fast, it must be right).
//!
//! Timing model (classic WS skew): activation row `b` enters array row
//! `r` at cycle `b + r` and reaches column `c` at `b + r + c`; a tile of
//! `BS` rows therefore occupies the array for `BS + R + C - 2` cycles.
//! Fill/drain always traverses the *physical* R and C (partial tiles pass
//! through idle PEs), which is exactly the paper's "imperfect tiling"
//! utilization loss. Coefficient loads add `tile_rows` cycles per tile
//! under `WeightLoad::Counted` and zero under `Amortized` (double
//! buffering), matching `analytic`.

use crate::arch::{ArrayConfig, PeKind, ScalarPe, VectorPe, WeightLoad};
use crate::sim::stats::SimStats;
use crate::tensor::Tensor;

/// Stats plus the computed GEMM output (i32 accumulators).
#[derive(Debug)]
pub struct CycleOutput {
    pub stats: SimStats,
    pub out: Tensor<i32>,
}

fn tile_cycles(cfg: &ArrayConfig, bs: usize, load_rows: usize) -> (u64, u64) {
    let stream = (bs + cfg.rows + cfg.cols - 2) as u64;
    let load = match cfg.weight_load {
        WeightLoad::Amortized => 0,
        WeightLoad::Counted => load_rows as u64,
    };
    (stream, load)
}

/// Conventional scalar-PE array executing a dense GEMM
/// `a (BS x RED) @ w (RED x N)` — for KAN workloads `a` is the expanded
/// B-spline activation matrix (mostly zeros: the N:M sparsity the paper
/// measures at ~30% utilization).
pub fn run_conventional(cfg: &ArrayConfig, a: &Tensor<u8>, w: &Tensor<i8>) -> CycleOutput {
    assert_eq!(cfg.pe, PeKind::Scalar, "run_conventional needs scalar PEs");
    let (bs, red) = (a.shape()[0], a.shape()[1]);
    let (red2, n_out) = (w.shape()[0], w.shape()[1]);
    assert_eq!(red, red2);
    let (rr, cc) = (cfg.rows, cfg.cols);
    let mut out: Tensor<i32> = Tensor::zeros(&[bs, n_out]);
    let mut stats = SimStats::default();

    for k0 in (0..red).step_by(rr) {
        let rows_a = rr.min(red - k0);
        for n0 in (0..n_out).step_by(cc) {
            let cols_a = cc.min(n_out - n0);
            // load the stationary weight tile
            let mut pes: Vec<Vec<ScalarPe>> = (0..rows_a)
                .map(|r| {
                    (0..cols_a)
                        .map(|c| {
                            let mut pe = ScalarPe::default();
                            pe.load(*w.at(&[k0 + r, n0 + c]));
                            pe
                        })
                        .collect()
                })
                .collect();
            // stream the batch through (time-collapsed: the WS schedule is
            // deterministic, so iterating (b, r, c) enumerates exactly the
            // MACs that happen at cycle b + r + c)
            for b in 0..bs {
                for c in 0..cols_a {
                    let mut psum = 0i32;
                    for (r, row_pes) in pes.iter_mut().enumerate() {
                        psum = row_pes[c].step(*a.at(&[b, k0 + r]), psum);
                    }
                    *out.at_mut(&[b, n0 + c]) += psum;
                }
            }
            let useful: u64 = pes.iter().flatten().map(|pe| pe.useful_macs).sum();
            let (stream, load) = tile_cycles(cfg, bs, rr);
            stats.cycles += stream + load;
            stats.active_slots += cfg.lanes() as u64 * bs as u64;
            stats.useful_macs += useful;
            stats.tiles += 1;
        }
    }
    CycleOutput { stats, out }
}

/// KAN-SAs vector-PE array executing a KAN spline workload directly from
/// the B-spline unit's sparse view: `vals (BS x K x (P+1))`, `ks (BS x K)`
/// against `coeff (K x M x N)` — the Fig. 6 dataflow.
pub fn run_kansas_kan(
    cfg: &ArrayConfig,
    vals: &Tensor<u8>,
    ks: &Tensor<i32>,
    coeff: &Tensor<i8>,
) -> CycleOutput {
    let (n_pe, m_pe) = match cfg.pe {
        PeKind::Vector { n, m } => (n, m),
        PeKind::Scalar => panic!("run_kansas_kan needs vector PEs"),
    };
    let (bs, k_feats, n_lanes) = (vals.shape()[0], vals.shape()[1], vals.shape()[2]);
    assert_eq!(n_lanes, n_pe, "PE lanes {n_pe} != workload P+1 {n_lanes}");
    assert_eq!(coeff.shape()[0], k_feats);
    assert_eq!(coeff.shape()[1], m_pe, "PE registers {m_pe} != workload G+P");
    let n_out = coeff.shape()[2];
    let (rr, cc) = (cfg.rows, cfg.cols);
    let mut out: Tensor<i32> = Tensor::zeros(&[bs, n_out]);
    let mut stats = SimStats::default();

    for k0 in (0..k_feats).step_by(rr) {
        let rows_a = rr.min(k_feats - k0);
        for n0 in (0..n_out).step_by(cc) {
            let cols_a = cc.min(n_out - n0);
            let mut pes: Vec<Vec<VectorPe>> = (0..rows_a)
                .map(|r| {
                    (0..cols_a)
                        .map(|c| {
                            let mut pe = VectorPe::new(n_pe, m_pe);
                            let regs: Vec<i8> =
                                (0..m_pe).map(|j| *coeff.at(&[k0 + r, j, n0 + c])).collect();
                            pe.load(&regs);
                            pe
                        })
                        .collect()
                })
                .collect();
            for b in 0..bs {
                for c in 0..cols_a {
                    let mut psum = 0i32;
                    for (r, row_pes) in pes.iter_mut().enumerate() {
                        let feat = k0 + r;
                        let off = vals.offset(&[b, feat, 0]);
                        let v = &vals.data()[off..off + n_pe];
                        let k = *ks.at(&[b, feat]) as usize;
                        psum = row_pes[c].step_kan(v, k, psum);
                    }
                    *out.at_mut(&[b, n0 + c]) += psum;
                }
            }
            let useful: u64 = pes.iter().flatten().map(|pe| pe.useful_macs).sum();
            let (stream, load) = tile_cycles(cfg, bs, rr * m_pe);
            stats.cycles += stream + load;
            stats.active_slots += cfg.lanes() as u64 * bs as u64;
            stats.useful_macs += useful;
            stats.tiles += 1;
        }
    }
    CycleOutput { stats, out }
}

/// KAN-SAs vector-PE array on a *dense* workload (the MLP base term):
/// each PE row covers N consecutive reduction rows, all lanes dense.
pub fn run_kansas_dense(cfg: &ArrayConfig, a: &Tensor<u8>, w: &Tensor<i8>) -> CycleOutput {
    let (n_pe, m_pe) = match cfg.pe {
        PeKind::Vector { n, m } => (n, m),
        PeKind::Scalar => panic!("run_kansas_dense needs vector PEs"),
    };
    let (bs, red) = (a.shape()[0], a.shape()[1]);
    let n_out = w.shape()[1];
    assert_eq!(w.shape()[0], red);
    let (rr, cc) = (cfg.rows, cfg.cols);
    let tile_red = rr * n_pe;
    let mut out: Tensor<i32> = Tensor::zeros(&[bs, n_out]);
    let mut stats = SimStats::default();

    for k0 in (0..red).step_by(tile_red) {
        for n0 in (0..n_out).step_by(cc) {
            let cols_a = cc.min(n_out - n0);
            // rows of PEs actually covering reduction rows in this tile
            let rows_a = rr.min((red - k0).div_ceil(n_pe));
            let mut pes: Vec<Vec<VectorPe>> = (0..rows_a)
                .map(|r| {
                    (0..cols_a)
                        .map(|c| {
                            let mut pe = VectorPe::new(n_pe, m_pe);
                            let mut regs = vec![0i8; m_pe];
                            for j in 0..n_pe {
                                let row = k0 + r * n_pe + j;
                                if row < red {
                                    regs[j] = *w.at(&[row, n0 + c]);
                                }
                            }
                            pe.load(&regs);
                            pe
                        })
                        .collect()
                })
                .collect();
            for b in 0..bs {
                for c in 0..cols_a {
                    let mut psum = 0i32;
                    for (r, row_pes) in pes.iter_mut().enumerate() {
                        let start = k0 + r * n_pe;
                        let take = n_pe.min(red - start);
                        let off = a.offset(&[b, start]);
                        let v = &a.data()[off..off + take];
                        psum = row_pes[c].step_dense(v, psum);
                    }
                    *out.at_mut(&[b, n0 + c]) += psum;
                }
            }
            let useful: u64 = pes.iter().flatten().map(|pe| pe.useful_macs).sum();
            let (stream, load) = tile_cycles(cfg, bs, rr * n_pe);
            stats.cycles += stream + load;
            stats.active_slots += cfg.lanes() as u64 * bs as u64;
            stats.useful_macs += useful;
            stats.tiles += 1;
        }
    }
    CycleOutput { stats, out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArrayConfig;
    use crate::sim::synth;
    use crate::tensor::matmul_u8_i8;
    use crate::util::rng::{check, Rng};

    #[test]
    fn conventional_computes_the_gemm() {
        check(20, 51, |rng: &mut Rng| {
            let bs = 1 + rng.below(6);
            let red = 1 + rng.below(20);
            let n = 1 + rng.below(9);
            let a = synth::dense_activations(bs, red, rng);
            let w = synth::weights(red, n, rng);
            let cfg = ArrayConfig::conventional(1 + rng.below(6), 1 + rng.below(6));
            let got = run_conventional(&cfg, &a, &w);
            assert_eq!(got.out, matmul_u8_i8(&a, &w), "cfg {}", cfg.label());
        });
    }

    #[test]
    fn kansas_kan_equals_conventional_on_expanded_matrix() {
        // the N:M array must compute the same GEMM the scalar array does
        // on the dense expansion — the paper's equivalence claim
        check(15, 52, |rng: &mut Rng| {
            let g = 1 + rng.below(8);
            let p = 1 + rng.below(3);
            let bs = 1 + rng.below(5);
            let k_feats = 1 + rng.below(7);
            let n_out = 1 + rng.below(6);
            let (vals, ks, dense) = synth::kan_activations(bs, k_feats, g, p, rng);
            let coeff = synth::coefficients(k_feats, g + p, n_out, rng);
            let kcfg = ArrayConfig::kan_sas(1 + rng.below(4), 1 + rng.below(4), p + 1, g + p);
            let ccfg = ArrayConfig::conventional(3, 3);
            let flat = synth::flatten_coeff(&coeff);
            let a = run_kansas_kan(&kcfg, &vals, &ks, &coeff);
            let b = run_conventional(&ccfg, &dense, &flat);
            assert_eq!(a.out, b.out, "g={g} p={p}");
        });
    }

    #[test]
    fn kansas_dense_equals_conventional() {
        check(15, 53, |rng: &mut Rng| {
            let bs = 1 + rng.below(5);
            let red = 1 + rng.below(30);
            let n_out = 1 + rng.below(6);
            let a = synth::dense_activations(bs, red, rng);
            let w = synth::weights(red, n_out, rng);
            let n_pe = 1 + rng.below(4);
            let kcfg = ArrayConfig::kan_sas(1 + rng.below(4), 1 + rng.below(4), n_pe, n_pe + rng.below(5));
            let got = run_kansas_dense(&kcfg, &a, &w);
            assert_eq!(got.out, matmul_u8_i8(&a, &w));
        });
    }

    #[test]
    fn conventional_utilization_is_nm_density_without_tiling_loss() {
        // Array dims dividing the workload exactly and BS >> R+C: the only
        // losses left are B-spline sparsity — at most (P+1)/(G+P) density —
        // plus the LUT-quantization zeros near the support edges (values
        // whose uint8 quantization rounds to 0), which push measured
        // density slightly *below* the ideal N/M. Useful MACs must equal
        // the actual non-zero count exactly.
        let (g, p) = (5usize, 3usize);
        let mut rng = Rng::new(7);
        let (_vals, _ks, dense) = synth::kan_activations(512, 4, g, p, &mut rng);
        let w = synth::weights(4 * (g + p), 8, &mut rng);
        let cfg = ArrayConfig::conventional(8, 8);
        let got = run_conventional(&cfg, &dense, &w);
        let nnz = dense.data().iter().filter(|&&v| v != 0).count() as u64;
        assert_eq!(got.stats.useful_macs, nnz * 8, "exact useful-MAC accounting");
        let bound = (p + 1) as f64 / (g + p) as f64;
        let u = got.stats.utilization();
        assert!(u <= bound + 1e-9, "utilization {u} exceeds N:M bound {bound}");
        assert!(u > 0.8 * bound, "utilization {u} far below N:M bound {bound}");
    }

    #[test]
    fn kansas_utilization_near_one_without_tiling_loss() {
        // All N lanes carry potentially-non-zero values; the residual gap
        // to 1.0 is fill/drain skew plus the LUT-quantization zeros (see
        // the conventional test above). Useful MACs are counted exactly.
        let (g, p) = (5usize, 3usize);
        let mut rng = Rng::new(8);
        let (vals, ks, _dense) = synth::kan_activations(512, 8, g, p, &mut rng);
        let coeff = synth::coefficients(8, g + p, 8, &mut rng);
        let cfg = ArrayConfig::kan_sas(8, 8, p + 1, g + p);
        let got = run_kansas_kan(&cfg, &vals, &ks, &coeff);
        let nnz = vals.data().iter().filter(|&&v| v != 0).count() as u64;
        assert_eq!(got.stats.useful_macs, nnz * 8, "exact useful-MAC accounting");
        let u = got.stats.utilization();
        assert!(u > 0.82, "KAN-SAs utilization should approach 1, got {u}");
        // and it must dominate the conventional bound by a wide margin
        assert!(u > 1.6 * (p + 1) as f64 / (g + p) as f64);
    }

    #[test]
    fn counted_weight_load_increases_cycles() {
        let mut rng = Rng::new(9);
        let a = synth::dense_activations(16, 32, &mut rng);
        let w = synth::weights(32, 8, &mut rng);
        let mut cfg = ArrayConfig::conventional(8, 8);
        let amortized = run_conventional(&cfg, &a, &w).stats.cycles;
        cfg.weight_load = WeightLoad::Counted;
        let counted = run_conventional(&cfg, &a, &w).stats.cycles;
        assert_eq!(counted, amortized + 4 /*tiles*/ * 8 /*rows*/);
    }
}
