//! Synthetic quantized data generators for the cycle simulator and tests.

use crate::bspline::{BsplineUnit, Lut};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Random dense uint8 activations with no zeros (the paper's evaluation
/// "focuses solely on B-spline sparsity" — other dynamic sparsity is
/// deliberately excluded).
pub fn dense_activations(bs: usize, red: usize, rng: &mut Rng) -> Tensor<u8> {
    let data = (0..bs * red).map(|_| 1 + rng.below(255) as u8).collect();
    Tensor::from_vec(data, &[bs, red])
}

/// Random int8 weights (zero allowed; weight sparsity is out of scope and
/// does not affect the activation-operand utilization definition).
pub fn weights(red: usize, n: usize, rng: &mut Rng) -> Tensor<i8> {
    let data = (0..red * n).map(|_| rng.range_i64(-127, 127) as i8).collect();
    Tensor::from_vec(data, &[red, n])
}

/// Random spline coefficients `(K, M, N)`.
pub fn coefficients(k_feats: usize, m: usize, n: usize, rng: &mut Rng) -> Tensor<i8> {
    let data = (0..k_feats * m * n).map(|_| rng.range_i64(-127, 127) as i8).collect();
    Tensor::from_vec(data, &[k_feats, m, n])
}

/// `(K, M, N)` coefficients -> `(K*M, N)` dense weight matrix (what the
/// conventional array loads).
pub fn flatten_coeff(coeff: &Tensor<i8>) -> Tensor<i8> {
    let s = coeff.shape();
    coeff.clone().reshape(&[s[0] * s[1], s[2]])
}

/// Run random quantized inputs through a real B-spline unit, returning
/// the sparse view `(vals (BS,K,P+1), ks (BS,K))` and the dense
/// expansion `(BS, K*(G+P))` a conventional array would consume.
pub fn kan_activations(
    bs: usize,
    k_feats: usize,
    g: usize,
    p: usize,
    rng: &mut Rng,
) -> (Tensor<u8>, Tensor<i32>, Tensor<u8>) {
    let unit = BsplineUnit::new(Lut::build(p), g);
    let m = g + p;
    let mut vals = Vec::with_capacity(bs * k_feats * (p + 1));
    let mut ks = Vec::with_capacity(bs * k_feats);
    let mut dense = Vec::with_capacity(bs * k_feats * m);
    for _ in 0..bs * k_feats {
        let xq = rng.below(256) as u8;
        let (v, k) = unit.eval_into(xq);
        vals.extend_from_slice(v);
        ks.push(k as i32);
        dense.extend_from_slice(&unit.eval_dense(xq));
    }
    (
        Tensor::from_vec(vals, &[bs, k_feats, p + 1]),
        Tensor::from_vec(ks, &[bs, k_feats]),
        Tensor::from_vec(dense, &[bs, k_feats * m]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_has_no_zeros() {
        let mut rng = Rng::new(1);
        let a = dense_activations(4, 100, &mut rng);
        assert!(a.data().iter().all(|&v| v != 0));
    }

    #[test]
    fn kan_sparse_and_dense_agree() {
        let mut rng = Rng::new(2);
        let (vals, ks, dense) = kan_activations(3, 4, 5, 3, &mut rng);
        assert_eq!(vals.shape(), &[3, 4, 4]);
        assert_eq!(ks.shape(), &[3, 4]);
        assert_eq!(dense.shape(), &[3, 32]);
        // total mass matches between views
        let sv: u32 = vals.data().iter().map(|&v| v as u32).sum();
        let sd: u32 = dense.data().iter().map(|&v| v as u32).sum();
        assert_eq!(sv, sd);
    }

    #[test]
    fn flatten_is_row_major() {
        let mut rng = Rng::new(3);
        let c = coefficients(2, 3, 4, &mut rng);
        let f = flatten_coeff(&c);
        assert_eq!(f.shape(), &[6, 4]);
        assert_eq!(f.at(&[4, 2]), c.at(&[1, 1, 2]));
    }
}
