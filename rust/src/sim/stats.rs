//! Simulation statistics and aggregation.

use std::ops::AddAssign;

/// Counts from simulating one or more workloads on one array config.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Total clock cycles the array was busy (streaming + fill/drain
    /// skew + optional weight loads): the *runtime* metric of Fig. 7b.
    pub cycles: u64,
    /// Multiplier-lane slots during the active streaming window
    /// (lanes * BS per tile): the *utilization* denominator of Figs.
    /// 7a/8. Fill/drain skew counts toward runtime but not utilization —
    /// matching the paper, whose conventional-SA MNIST-KAN utilization
    /// (~30%) equals the N:M density bound 4/13 exactly, which is only
    /// possible if the skew is excluded.
    pub active_slots: u64,
    /// MACs whose activation operand was non-zero and inside the
    /// unpadded tile region.
    pub useful_macs: u64,
    /// Number of coefficient tiles processed.
    pub tiles: u64,
}

impl SimStats {
    /// PE utilization per the paper: useful MACs over active lane-slots.
    pub fn utilization(&self) -> f64 {
        if self.active_slots == 0 {
            return 0.0;
        }
        self.useful_macs as f64 / self.active_slots as f64
    }
}

impl AddAssign for SimStats {
    fn add_assign(&mut self, rhs: Self) {
        self.cycles += rhs.cycles;
        self.active_slots += rhs.active_slots;
        self.useful_macs += rhs.useful_macs;
        self.tiles += rhs.tiles;
    }
}

/// Mean utilization and total cycles across per-workload stats (Fig. 7
/// averages applications this way: utilization is averaged, runtimes
/// summed per app then averaged).
pub fn aggregate(stats: &[SimStats]) -> SimStats {
    let mut total = SimStats::default();
    for s in stats {
        total += *s;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_ratio() {
        let s = SimStats { cycles: 10, active_slots: 100, useful_macs: 30, tiles: 1 };
        assert!((s.utilization() - 0.3).abs() < 1e-12);
        assert_eq!(SimStats::default().utilization(), 0.0);
    }

    #[test]
    fn aggregate_sums() {
        let a = SimStats { cycles: 5, active_slots: 50, useful_macs: 10, tiles: 1 };
        let b = SimStats { cycles: 7, active_slots: 70, useful_macs: 30, tiles: 2 };
        let t = aggregate(&[a, b]);
        assert_eq!(t.cycles, 12);
        assert_eq!(t.active_slots, 120);
        assert_eq!(t.useful_macs, 40);
        assert_eq!(t.tiles, 3);
    }
}
