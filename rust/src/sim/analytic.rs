//! Closed-form simulation — the fast path for the design-space sweeps.
//!
//! Cycle and lane-slot counts are *identical* to the event-exact
//! [`super::cycle`] engine (property-tested in `rust/tests/`); useful-MAC
//! counts use the exact N:M expectation (all P+1 window values non-zero),
//! which the cycle engine confirms to within the ~1/256 LUT-row-0 effect.

use crate::arch::{ArrayConfig, PeKind, WeightLoad};
use crate::sim::stats::SimStats;
use crate::sim::workload::{GemmKind, Workload};

/// Tiles needed to cover `dim` with tiles of `size`.
pub fn tiles(dim: usize, size: usize) -> u64 {
    dim.div_ceil(size) as u64
}

/// Reduction-dimension tile count for this (array, workload) pair.
fn k_tiles(cfg: &ArrayConfig, wl: &Workload) -> u64 {
    match (cfg.pe, wl.kind) {
        (PeKind::Scalar, _) => tiles(wl.expanded_reduction(), cfg.rows),
        // one feature per PE row; the M-wide basis lives in the registers
        (PeKind::Vector { .. }, GemmKind::KanSpline { .. }) => tiles(wl.k_feats, cfg.rows),
        (PeKind::Vector { n, .. }, GemmKind::Dense) => tiles(wl.k_feats, cfg.rows * n),
    }
}

/// Coefficient rows loaded per tile (the `Counted` policy's cost).
fn load_rows(cfg: &ArrayConfig, wl: &Workload) -> u64 {
    match (cfg.pe, wl.kind) {
        (PeKind::Scalar, _) => cfg.rows as u64,
        (PeKind::Vector { m, .. }, GemmKind::KanSpline { .. }) => (cfg.rows * m) as u64,
        (PeKind::Vector { n, .. }, GemmKind::Dense) => (cfg.rows * n) as u64,
    }
}

/// Check that a vector-PE array can execute a workload directly (the mux
/// depth and lane count are design-time parameters fixed to the layer's
/// N = P+1, M = G+P, Sec. IV-B).
pub fn compatible(cfg: &ArrayConfig, wl: &Workload) -> bool {
    match (cfg.pe, wl.kind) {
        (PeKind::Scalar, _) => true,
        (PeKind::Vector { .. }, GemmKind::Dense) => true,
        (PeKind::Vector { n, m }, GemmKind::KanSpline { g, p }) => n == p + 1 && m == g + p,
    }
}

/// Closed-form stats for one workload on one array.
pub fn simulate(cfg: &ArrayConfig, wl: &Workload) -> SimStats {
    assert!(
        compatible(cfg, wl),
        "array {} cannot execute workload {} directly",
        cfg.label(),
        wl.name
    );
    let kt = k_tiles(cfg, wl);
    let nt = tiles(wl.n_out, cfg.cols);
    let stream = (wl.bs + cfg.rows + cfg.cols - 2) as u64;
    let load = match cfg.weight_load {
        WeightLoad::Amortized => 0,
        WeightLoad::Counted => load_rows(cfg, wl),
    };
    let tiles_total = kt * nt;
    let cycles = tiles_total * (stream + load);
    SimStats {
        cycles,
        // utilization denominator: lanes during the BS streaming window
        active_slots: cfg.lanes() as u64 * wl.bs as u64 * tiles_total,
        useful_macs: wl.useful_macs(),
        tiles: tiles_total,
    }
}

/// Simulate a list of workloads (an application) and aggregate.
pub fn simulate_app(cfg: &ArrayConfig, workloads: &[Workload]) -> SimStats {
    let mut total = SimStats::default();
    for wl in workloads {
        total += simulate(cfg, wl);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiles_ceil() {
        assert_eq!(tiles(10, 4), 3);
        assert_eq!(tiles(8, 4), 2);
        assert_eq!(tiles(1, 16), 1);
    }

    #[test]
    fn scalar_vs_vector_cycle_ratio_is_m() {
        // the paper's Table I note: a scalar PE array needs (G+P)x more
        // cycles than the N:M array on the same KAN workload (exact when
        // the tiling divides evenly)
        let (g, p) = (3usize, 3usize); // M = 6, N = 4
        let wl = Workload::kan("w", 64, 24, 8, g, p);
        let conv = simulate(&ArrayConfig::conventional(8, 8), &wl);
        let kan = simulate(&ArrayConfig::kan_sas(8, 8, p + 1, g + p), &wl);
        assert_eq!(conv.cycles, (g + p) as u64 * kan.cycles);
    }

    #[test]
    fn utilization_bounds() {
        let wl = Workload::kan("w", 32, 22, 10, 5, 3);
        for cfg in [
            ArrayConfig::conventional(4, 4),
            ArrayConfig::conventional(32, 32),
            ArrayConfig::kan_sas(16, 16, 4, 8),
        ] {
            if compatible(&cfg, &wl) {
                let s = simulate(&cfg, &wl);
                let u = s.utilization();
                assert!(u > 0.0 && u <= 1.0, "{}: {u}", cfg.label());
            }
        }
    }

    #[test]
    fn conventional_utilization_upper_bounded_by_density() {
        let wl = Workload::kan("w", 1024, 64, 64, 10, 3); // density 4/13
        let s = simulate(&ArrayConfig::conventional(8, 8), &wl);
        assert!(s.utilization() <= 4.0 / 13.0 + 1e-9);
        assert!(s.utilization() > 0.25); // big workload: tiling loss small
    }

    #[test]
    fn kansas_utilization_approaches_one() {
        let wl = Workload::kan("w", 2048, 64, 64, 5, 3);
        let s = simulate(&ArrayConfig::kan_sas(16, 16, 4, 8), &wl);
        assert!(s.utilization() > 0.9, "{}", s.utilization());
    }

    #[test]
    fn incompatible_rejected() {
        let wl = Workload::kan("w", 4, 4, 4, 10, 3); // needs 4:13
        assert!(!compatible(&ArrayConfig::kan_sas(4, 4, 4, 8), &wl));
        assert!(compatible(&ArrayConfig::kan_sas(4, 4, 4, 13), &wl));
        assert!(compatible(&ArrayConfig::conventional(4, 4), &wl));
    }

    #[test]
    fn dense_on_vector_covers_n_rows_per_pe() {
        let wl = Workload::dense("d", 16, 64, 8);
        let conv = simulate(&ArrayConfig::conventional(8, 8), &wl);
        let kan = simulate(&ArrayConfig::kan_sas(8, 8, 4, 8), &wl);
        // 64 rows: scalar needs 8 k-tiles, vector 2 — 4x fewer
        assert_eq!(conv.tiles, 8);
        assert_eq!(kan.tiles, 2);
        assert_eq!(conv.useful_macs, kan.useful_macs);
    }

    #[test]
    fn app_aggregation_adds() {
        let wls = vec![
            Workload::kan("a", 8, 4, 4, 5, 3),
            Workload::dense("b", 8, 16, 4),
        ];
        let cfg = ArrayConfig::kan_sas(4, 4, 4, 8);
        let total = simulate_app(&cfg, &wls);
        let sum: u64 = wls.iter().map(|w| simulate(&cfg, w).cycles).sum();
        assert_eq!(total.cycles, sum);
    }
}
