//! Cycle-level simulation of KAN GEMM workloads on weight-stationary
//! systolic arrays (paper Sec. V-C methodology).
//!
//! Two engines share one set of definitions:
//!
//! * [`cycle`] — event-exact: streams actual (quantized) activation data
//!   through functional PEs tile by tile, counting per-lane useful MACs
//!   and cycles. The ground truth; used by tests and small workloads.
//! * [`analytic`] — closed-form counts with a density parameter; matches
//!   `cycle` exactly on cycles/slots (property-tested) and is what the
//!   design-space sweeps (Figs. 7-8) run, since ResKAN18-scale workloads
//!   make per-event simulation unnecessary.
//!
//! Definitions (used consistently everywhere):
//! * a *lane-slot* is one multiplier lane for one active cycle;
//! * a MAC is *useful* iff its activation operand is non-zero and it
//!   falls inside the unpadded region of the tile;
//! * utilization = useful MACs / lane-slots — the paper's "computations
//!   involving non-zero B-spline activations" per PE resource.

pub mod analytic;
pub mod cycle;
pub mod stats;
pub mod synth;
pub mod workload;

pub use stats::SimStats;
pub use workload::{GemmKind, Workload};
