//! GEMM workload descriptions (the unit of work the array executes).

/// What the left-hand matrix of the GEMM is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKind {
    /// B-spline activation matrix from a KAN layer with grid `g`,
    /// degree `p`: logical shape `(BS, K*(G+P))` with the paper's
    /// dynamic N:M structure (N = P+1 non-zeros per feature).
    KanSpline { g: usize, p: usize },
    /// Dense activations (the MLP/base term of Eq. 1, or any non-KAN
    /// layer): shape `(BS, K)`.
    Dense,
}

impl GemmKind {
    pub fn is_kan(&self) -> bool {
        matches!(self, GemmKind::KanSpline { .. })
    }
}

/// One GEMM to run: `(BS, reduction) x (reduction, n_out)`.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    /// Batch rows streamed through the array.
    pub bs: usize,
    /// Input features K (pre-expansion for KAN workloads).
    pub k_feats: usize,
    /// Output columns N of the layer.
    pub n_out: usize,
    pub kind: GemmKind,
}

impl Workload {
    pub fn kan(name: &str, bs: usize, k_feats: usize, n_out: usize, g: usize, p: usize) -> Self {
        assert!(bs > 0 && k_feats > 0 && n_out > 0 && g >= 1 && p >= 1);
        Self { name: name.to_string(), bs, k_feats, n_out, kind: GemmKind::KanSpline { g, p } }
    }

    pub fn dense(name: &str, bs: usize, k_feats: usize, n_out: usize) -> Self {
        assert!(bs > 0 && k_feats > 0 && n_out > 0);
        Self { name: name.to_string(), bs, k_feats, n_out, kind: GemmKind::Dense }
    }

    /// Length of the reduction dimension as the *conventional* array sees
    /// it: K*(G+P) for spline workloads (the dense B matrix), K otherwise.
    pub fn expanded_reduction(&self) -> usize {
        match self.kind {
            GemmKind::KanSpline { g, p } => self.k_feats * (g + p),
            GemmKind::Dense => self.k_feats,
        }
    }

    /// MACs a dense execution of this GEMM performs (the roofline count).
    pub fn dense_macs(&self) -> u64 {
        self.bs as u64 * self.expanded_reduction() as u64 * self.n_out as u64
    }

    /// Expected useful MACs: only non-zero B-spline activations multiply
    /// (density (P+1)/(G+P) of the expanded reduction), everything for
    /// dense workloads. (Exact zeros from LUT row 0 are measure-~1/256
    /// and are captured by the cycle simulator, not this expectation.)
    pub fn useful_macs(&self) -> u64 {
        match self.kind {
            GemmKind::KanSpline { g, p } => {
                self.bs as u64 * (self.k_feats * (p + 1)) as u64 * self.n_out as u64
                    + 0 * g as u64
            }
            GemmKind::Dense => self.dense_macs(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_and_counts() {
        let w = Workload::kan("t", 32, 22, 10, 3, 3);
        assert_eq!(w.expanded_reduction(), 22 * 6);
        assert_eq!(w.dense_macs(), 32 * 132 * 10);
        assert_eq!(w.useful_macs(), 32 * 22 * 4 * 10);

        let d = Workload::dense("d", 8, 64, 16);
        assert_eq!(d.expanded_reduction(), 64);
        assert_eq!(d.useful_macs(), d.dense_macs());
    }

    #[test]
    fn kan_density_is_n_over_m() {
        let w = Workload::kan("t", 4, 10, 5, 10, 3); // 4:13
        let density = w.useful_macs() as f64 / w.dense_macs() as f64;
        assert!((density - 4.0 / 13.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_dims() {
        Workload::dense("bad", 0, 1, 1);
    }
}
