//! Hard acceptance gate for the planned execution core: after warmup,
//! `Engine::forward_into` / `Engine::forward_staged` must perform ZERO
//! heap allocations, measured by installing a counting global allocator
//! in this test binary.
//!
//! Kept to a single `#[test]` on purpose — the counters are process-wide
//! and the default harness runs tests of one binary concurrently, so a
//! second test here could allocate inside the measured window.

use kan_sas::kan::{Engine, Precision, QuantizedModel, Scratch};
use kan_sas::util::alloc_count::{self, CountingAllocator};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

#[test]
fn planned_forward_is_allocation_free_after_warmup() {
    let in_dim = 32usize;
    let engine =
        Engine::new(QuantizedModel::synthetic("zero_alloc", &[in_dim, 48, 24, 10], 5, 3, 7));
    let mk = |bs: usize| -> Vec<u8> {
        (0..bs * in_dim).map(|i| (i.wrapping_mul(131) % 256) as u8).collect()
    };
    let x16 = mk(16);
    let x3 = mk(3);

    // warmup: grows the arena to the peak batch size (16) on both paths
    let mut scratch = Scratch::new();
    let want16 = engine.forward_into(&x16, 16, &mut scratch).unwrap().to_vec();
    let want3 = engine.forward_into(&x3, 3, &mut scratch).unwrap().to_vec();
    scratch.stage_input(x16.len()).extend_from_slice(&x16);
    engine.forward_staged(16, &mut scratch).unwrap();

    let before = alloc_count::events();
    for _ in 0..16 {
        // external-input path, peak batch
        let t = engine.forward_into(&x16, 16, &mut scratch).unwrap();
        assert_eq!(t, &want16[..]);
        // shrunken batch through the same arena
        let t = engine.forward_into(&x3, 3, &mut scratch).unwrap();
        assert_eq!(t, &want3[..]);
        // gather-into-staging path (what pool workers run)
        scratch.stage_input(x16.len()).extend_from_slice(&x16);
        let t = engine.forward_staged(16, &mut scratch).unwrap();
        assert_eq!(t, &want16[..]);
    }
    let events = alloc_count::events() - before;
    assert_eq!(
        events, 0,
        "steady-state planned forwards must not touch the heap ({events} allocator events)"
    );

    // a pre-sized arena is allocation-free from the very first forward
    let mut sized = Scratch::for_plan(engine.plan(), 16);
    sized.stage_input(x16.len()).extend_from_slice(&x16);
    let before = alloc_count::events();
    let t = engine.forward_staged(16, &mut sized).unwrap();
    assert_eq!(t, &want16[..]);
    assert_eq!(alloc_count::events() - before, 0, "Scratch::for_plan must pre-size everything");

    // mixed-precision plans route through the packed int4 kernel entry
    // points; they must hit the same zero-allocation bar in steady state
    let e4 = Engine::new(QuantizedModel::synthetic_mixed(
        "zero_alloc4",
        &[in_dim, 48, 24, 10],
        5,
        3,
        7,
        &[Precision::Int4, Precision::Int8, Precision::Int4],
    ));
    let mut s4 = Scratch::new();
    let want4 = e4.forward_into(&x16, 16, &mut s4).unwrap().to_vec();
    e4.forward_into(&x3, 3, &mut s4).unwrap();
    let before = alloc_count::events();
    for _ in 0..16 {
        let t = e4.forward_into(&x16, 16, &mut s4).unwrap();
        assert_eq!(t, &want4[..]);
        e4.forward_into(&x3, 3, &mut s4).unwrap();
    }
    let events = alloc_count::events() - before;
    assert_eq!(
        events, 0,
        "packed int4 layers must not touch the heap in steady state ({events} allocator events)"
    );
}
