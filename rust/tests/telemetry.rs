//! Integration tests for the telemetry spine driven through the public
//! gateway API: ring overflow degrades to drop-and-count (never
//! corrupting serving conservation), collector totals reconcile with the
//! authoritative gateway counters when nothing is dropped, and
//! `trace_sample` produces complete admission→respond spans plus a
//! flight recorder that remembers registration.

mod common;

use std::time::Duration;

use kan_sas::arch::ArrayConfig;
use kan_sas::coordinator::{
    BatchPolicy, ChurnKind, Dispatch, GatewayBuilder, GatewayConfig, QuotaPolicy, ShedPolicy,
    TelemetryConfig,
};
use kan_sas::kan::{Engine, QuantizedModel};

fn engine(name: &str) -> Engine {
    Engine::new(QuantizedModel::synthetic(name, &[8, 12, 10], 5, 3, 31))
}

fn config(telemetry: TelemetryConfig) -> GatewayConfig {
    GatewayConfig {
        replicas: 1,
        queue_cap: 64,
        shed: ShedPolicy::Block,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        dispatch: Dispatch::FairSteal,
        quota: QuotaPolicy::None,
        telemetry,
        ..Default::default()
    }
}

/// A 2-slot ring under serial hammering must overflow; overflow shows up
/// in `dropped_events` and the collector's view undercounts — but the
/// gateway's own conservation counters stay exact, because emission
/// never blocks and drops never touch the serving path.
#[test]
fn ring_overflow_drops_and_counts_without_breaking_serving() {
    let tcfg = TelemetryConfig {
        ring_capacity: 2,
        window: Duration::from_millis(100),
        ..TelemetryConfig::default()
    };
    let mut b = GatewayBuilder::with_config(config(tcfg));
    let id = b.register("tiny_ring", engine("tiny_ring"));
    let gw = b.start();
    let tel = gw.telemetry();
    let h = gw.handle(id);
    for i in 0..500u64 {
        let r = h.infer_q(vec![(i % 251) as u8; 8]).unwrap();
        assert_eq!(r.t.len(), 10);
    }
    let stats = gw.shutdown();
    let dropped = tel.dropped_events();
    assert!(dropped > 0, "2-slot rings under 500 serial requests must overflow");
    let ms = &stats.per_model[0];
    assert_eq!(ms.submitted, 500);
    assert_eq!(ms.completed, 500);
    assert_eq!(ms.submitted, ms.completed + ms.shed + ms.failed);
    let snap = tel.snapshot();
    assert_eq!(snap.dropped_events, dropped);
    let t0 = &snap.tenants[0];
    assert!(
        t0.totals.completed <= ms.completed,
        "a lossy collector may undercount but never overcount"
    );
}

/// With the default 8192-slot rings nothing drops, so the collector's
/// cumulative totals reconcile exactly with the gateway counters, and
/// window summaries carry well-formed gauges.
#[test]
fn collector_totals_reconcile_with_gateway_counters() {
    let tcfg =
        TelemetryConfig { window: Duration::from_millis(20), ..TelemetryConfig::default() };
    let mut b = GatewayBuilder::with_config(config(tcfg));
    let id = b.register("windowed", engine("windowed"));
    let gw = b.start();
    let tel = gw.telemetry();
    let h = gw.handle(id);
    for burst in 0..4u64 {
        for i in 0..40u64 {
            let r = h.infer_q(vec![((burst * 40 + i) % 251) as u8; 8]).unwrap();
            assert_eq!(r.t.len(), 10);
        }
        // bounded-poll instead of a fixed idle: wait for the collector
        // tick that drains this burst (the same tick rolls any window
        // whose boundary has already passed)
        let want = (burst + 1) * 40;
        assert!(
            common::poll_until(Duration::from_secs(2), || {
                tel.snapshot().tenants.first().is_some_and(|t| t.totals.completed >= want)
            }),
            "collector drains burst {burst} within the poll bound"
        );
    }
    // served traffic must leave at least one *completed* window behind;
    // wait for the roll rather than guessing an idle duration
    assert!(
        common::poll_until(Duration::from_secs(2), || {
            tel.snapshot().tenants.first().is_some_and(|t| t.window.is_some())
        }),
        "a window boundary passes and rolls a summary"
    );
    let stats = gw.shutdown();
    assert_eq!(tel.dropped_events(), 0, "default rings must absorb this load");
    let snap = tel.snapshot();
    let t0 = &snap.tenants[0];
    assert_eq!(t0.name, "windowed");
    assert!(t0.live);
    assert_eq!(t0.totals.admitted, 160);
    assert_eq!(t0.totals.completed, 160);
    assert_eq!(t0.totals.shed, 0);
    assert_eq!(stats.per_model[0].completed, 160);
    assert!(t0.totals.batches >= 1);
    let w = t0.window.expect("served traffic must leave a window summary");
    assert!(w.end_us > w.start_us);
    assert!(w.throughput_rps >= 0.0);
    assert!(w.shed_rate == 0.0);
    if let Some(q) = w.queue {
        assert!(q.p50_us <= q.p95_us && q.p95_us <= q.max_us);
    }
    if let Some(s) = w.service {
        assert!(s.p50_us <= s.p99_us && s.p99_us <= s.max_us);
    }
}

/// `trace_sample: 1` spans every request end to end: each span's stage
/// timestamps are monotonic, and the flight recorder retains both the
/// registration churn record and per-tenant lifecycle events.
#[test]
fn trace_sampling_builds_full_spans() {
    let tcfg = TelemetryConfig {
        trace_sample: 1,
        window: Duration::from_millis(50),
        ..TelemetryConfig::default()
    };
    let mut b = GatewayBuilder::with_config(config(tcfg));
    let id = b.register("spans", engine("spans"));
    let gw = b.start();
    let tel = gw.telemetry();
    let h = gw.handle(id);
    for i in 0..32u64 {
        let r = h.infer_q(vec![(i % 251) as u8; 8]).unwrap();
        assert_eq!(r.t.len(), 10);
    }
    let stats = gw.shutdown();
    assert_eq!(stats.per_model[0].completed, 32);
    assert_eq!(tel.dropped_events(), 0);
    let snap = tel.snapshot();
    assert_eq!(snap.spans.len(), 32, "sampling 1-in-1 must span every request");
    for s in &snap.spans {
        assert_eq!(s.tenant, "spans");
        assert!(s.responded_us >= s.admitted_us);
        if let Some(t) = s.enqueued_us {
            assert!(t >= s.admitted_us);
        }
        if let Some(t) = s.serve_us {
            assert!(t <= s.responded_us);
        }
        assert!(!s.timeline().is_empty());
    }
    // spans are moved out by the snapshot that observes them, so a
    // second snapshot never repeats a span (JSONL streams stay unique)
    assert!(tel.snapshot().spans.is_empty());

    let dump = tel.flight_dump();
    assert_eq!(dump.churn.len(), 1, "one registration, no churn");
    assert_eq!(dump.churn[0].kind, ChurnKind::Registered);
    assert_eq!(dump.churn[0].name, "spans");
    let (name, evs) = &dump.tenants[0];
    assert_eq!(name, "spans");
    assert!(!evs.is_empty(), "flight recorder must retain lifecycle events");
}
