//! Hard acceptance gate for the network front door's zero-alloc
//! steady state: after warmup, the frame codec (header encode/decode,
//! request/response encode into a reusable buffer, InferOk payload
//! decode into a reusable logits buffer) and the gateway-side
//! [`RowPool`] that admission decodes into must run with ZERO heap
//! allocations, measured by the counting global allocator (same
//! technique as `tests/zero_alloc.rs` / `tests/gateway_alloc.rs`).
//!
//! Kept to a single `#[test]` on purpose — the counters are
//! process-wide and the default harness runs tests of one binary
//! concurrently, so a second test here could allocate inside the
//! measured window.

use kan_sas::coordinator::net::{
    decode_ok_payload, encode_request, encode_response, FrameHeader, FrameType, HEADER_LEN,
};
use kan_sas::coordinator::RowPool;
use kan_sas::util::alloc_count::{self, CountingAllocator};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

#[test]
fn codec_and_row_pool_are_allocation_free_after_warmup() {
    let in_dim = 64usize;
    let out_dim = 10usize;

    // ---- frame codec, measured directly ----
    // warmup: one encode/decode cycle grows each reusable buffer to its
    // steady-state capacity
    let row = [9u8; 64];
    let logits = [123i64; 10];
    let mut req_buf: Vec<u8> = Vec::new();
    let mut resp_buf: Vec<u8> = Vec::new();
    let mut t_buf: Vec<i64> = Vec::new();
    encode_request(&mut req_buf, 1, 0, &row, 1_000, 2);
    encode_response(&mut resp_buf, 1, 50, 200, &logits);
    decode_ok_payload(&resp_buf[HEADER_LEN..], &mut t_buf).unwrap();

    let before = alloc_count::events();
    for i in 0..256u64 {
        encode_request(&mut req_buf, i, 0, &row, 1_000, 2);
        let hdr: &[u8; HEADER_LEN] = req_buf[..HEADER_LEN].try_into().expect("header slice");
        let h = FrameHeader::decode(hdr).expect("well-formed header");
        assert_eq!((h.ty, h.corr, h.len as usize), (FrameType::InferRequest, i, in_dim));

        encode_response(&mut resp_buf, i, 50, 200, &logits);
        let (q, s) = decode_ok_payload(&resp_buf[HEADER_LEN..], &mut t_buf).expect("payload");
        assert_eq!((q, s), (50, 200));
        assert_eq!(t_buf.len(), out_dim);
    }
    let events = alloc_count::events() - before;
    assert_eq!(
        events, 0,
        "steady-state frame encode/decode must not touch the heap ({events} allocator events)"
    );

    // ---- the admission-side row pool, measured directly ----
    // the server's reader acquires a pooled row, resizes it to in_dim,
    // fills it from the socket, and submits; the serving worker releases
    // it at gather — model that cycle here
    let pool = RowPool::new(in_dim, 8);
    let warm = pool.acquire();
    pool.release(warm);
    let before = alloc_count::events();
    for _ in 0..256 {
        let mut buf = pool.acquire(); // free-list hit: no allocation
        buf.resize(in_dim, 0); // within pre-sized capacity
        buf.copy_from_slice(&row);
        pool.release(buf); // back to the list: no allocation
    }
    let events = alloc_count::events() - before;
    assert_eq!(
        events, 0,
        "steady-state row acquire/fill/release must not touch the heap \
         ({events} allocator events)"
    );
    let (created, recycled, free) = pool.counts();
    assert_eq!(created, 1, "one warmup row serves the whole loop");
    assert_eq!(recycled, 256);
    assert_eq!(free, 1);
}
