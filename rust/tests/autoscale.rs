//! Deterministic autoscaler tests on the manual [`Clock`]: every
//! controller assertion is driven by synthetic [`FleetSignals`] or
//! explicit clock advances — zero wall-clock sleeps, so scale-up
//! latency, hysteresis, and the drain contract are exact, not timed.
//!
//! The gateway never spawns its background controller thread under a
//! manual clock; tests apply evaluations synchronously through
//! `Gateway::autoscale_apply` / `Gateway::autoscale_tick`, so a scaling
//! action can never race the assertion that observes it.

mod common;

use std::time::Duration;

use kan_sas::arch::ArrayConfig;
use kan_sas::coordinator::{
    AutoscaleConfig, BatchPolicy, Clock, Dispatch, DrainMode, FleetSignals, GatewayBuilder,
    GatewayConfig, QuotaPolicy, ServeError, ShedPolicy, TelemetryConfig,
};
use kan_sas::kan::{Engine, QuantizedModel};

fn engine(name: &str) -> Engine {
    Engine::new(QuantizedModel::synthetic(name, &[8, 12, 10], 5, 3, 31))
}

fn bounds(min: usize, max: usize, calm_windows: u32) -> AutoscaleConfig {
    AutoscaleConfig {
        min_workers: min,
        max_workers: max,
        slo_p95_us: 10_000,
        calm_windows,
        interval: Duration::from_millis(10),
        ..AutoscaleConfig::default()
    }
}

fn config(
    autoscale: Option<AutoscaleConfig>,
    clock: &Clock,
    queue_cap: usize,
    shed: ShedPolicy,
) -> GatewayConfig {
    GatewayConfig {
        replicas: 2, // ignored when autoscale geometry governs
        queue_cap,
        shed,
        // size-due batches: a manual clock never fires time-due windows
        policy: BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
        sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        dispatch: Dispatch::FairSteal,
        quota: QuotaPolicy::None,
        telemetry: TelemetryConfig::default(),
        autoscale,
        clock: clock.clone(),
        ..Default::default()
    }
}

/// A window whose worst-tenant p95 queueing delay is far over the SLO.
fn breach() -> FleetSignals {
    FleetSignals { p95_queue_us: 50_000, shed_rate: 0.0, depth_last: 0, windows: 1 }
}

/// An idle window: no queueing, no shedding — calm by definition.
fn calm() -> FleetSignals {
    FleetSignals::default()
}

/// Scale-up latency bound: from `min` the fleet reaches `max` within
/// ceil(log2(max/min)) breach evaluations — doubling each window — and
/// every applied event carries the manual clock's exact timestamp and
/// the signal that drove it.
#[test]
fn breach_reaches_max_within_log2_evaluations() {
    let clock = Clock::manual();
    let cfg = config(Some(bounds(1, 8, 3)), &clock, 64, ShedPolicy::Block);
    let mut b = GatewayBuilder::with_config(cfg);
    b.register("t", engine("t"));
    let gw = b.start();
    assert_eq!(gw.active_workers(), 1, "autoscale fleets start at min_workers");
    assert_eq!(gw.worker_slots(), 8, "slots are pre-sized to max_workers");

    for (i, (from, to)) in [(1usize, 2usize), (2, 4), (4, 8)].into_iter().enumerate() {
        clock.advance(Duration::from_micros(100));
        let ev = gw.autoscale_apply(&breach()).expect("a breach below max must scale up");
        assert_eq!((ev.from, ev.to), (from, to), "doubling, clamped to max");
        assert_eq!(ev.at_us, 100 * (i as u64 + 1), "events are stamped on the gateway clock");
        assert_eq!(ev.p95_queue_us, 50_000, "events record the driving signal");
        assert_eq!(gw.active_workers(), to);
    }
    assert!(gw.autoscale_apply(&breach()).is_none(), "at max a breach holds");
    assert_eq!(gw.active_workers(), 8);
    assert_eq!(gw.scale_events().len(), 3, "holds are not logged as events");
    assert!(gw.shutdown().conserved());
}

/// Hysteresis: K consecutive calm windows drain exactly one worker;
/// K-1 hold; a breach anywhere in the streak both scales up and resets
/// the count, so an oscillating load can never thrash the fleet.
#[test]
fn hysteresis_holds_through_k_minus_1_calm_windows() {
    let clock = Clock::manual();
    let cfg = config(Some(bounds(2, 4, 3)), &clock, 64, ShedPolicy::Block);
    let mut b = GatewayBuilder::with_config(cfg);
    b.register("t", engine("t"));
    let gw = b.start();
    assert_eq!(gw.active_workers(), 2);

    let ev = gw.autoscale_apply(&breach()).expect("breach scales up");
    assert_eq!((ev.from, ev.to), (2, 4));

    // K-1 calm windows: hold
    assert!(gw.autoscale_apply(&calm()).is_none());
    assert!(gw.autoscale_apply(&calm()).is_none());
    assert_eq!(gw.active_workers(), 4, "K-1 calm windows must not drain");
    // the Kth drains exactly one
    let ev = gw.autoscale_apply(&calm()).expect("K consecutive calm windows drain one");
    assert_eq!((ev.from, ev.to), (4, 3));

    // a breach mid-streak resets the counter: after it, K-1 calms are
    // again not enough, even though 2 calms already preceded the breach
    assert!(gw.autoscale_apply(&calm()).is_none());
    assert!(gw.autoscale_apply(&calm()).is_none());
    let ev = gw.autoscale_apply(&breach()).expect("below max, a breach scales up");
    assert_eq!((ev.from, ev.to), (3, 4));
    assert!(gw.autoscale_apply(&calm()).is_none());
    assert!(gw.autoscale_apply(&calm()).is_none(), "streak was reset by the breach");
    let ev = gw.autoscale_apply(&calm()).expect("fresh K-window streak drains again");
    assert_eq!((ev.from, ev.to), (4, 3));
    assert!(gw.shutdown().conserved());
}

/// `autoscale_tick` (the live-telemetry path) on an idle gateway: no
/// tenant reports a window, idle counts as calm, and the fleet drains
/// one worker every K ticks until it reaches `min_workers` — the
/// flash-crowd fleet shrinks back on its own.
#[test]
fn idle_ticks_drain_to_min() {
    let clock = Clock::manual();
    let cfg = config(Some(bounds(1, 4, 2)), &clock, 64, ShedPolicy::Block);
    let mut b = GatewayBuilder::with_config(cfg);
    b.register("t", engine("t"));
    let gw = b.start();
    gw.autoscale_apply(&breach()); // 1 -> 2
    gw.autoscale_apply(&breach()); // 2 -> 4
    assert_eq!(gw.active_workers(), 4);

    let mut drains = Vec::new();
    for _ in 0..6 {
        if let Some(ev) = gw.autoscale_tick() {
            drains.push((ev.from, ev.to));
        }
    }
    assert_eq!(drains, vec![(4, 3), (3, 2), (2, 1)], "one drain per K idle ticks");
    assert_eq!(gw.active_workers(), 1, "never below min_workers");
    assert!(gw.autoscale_tick().is_none(), "at min an idle tick holds");
    assert!(gw.shutdown().conserved());
}

/// Manual `scale_to` clamps to `1..=worker_slots` and reports the
/// resulting active count; a fixed (non-autoscale) gateway exposes no
/// autoscale surface at all.
#[test]
fn scale_to_clamps_and_fixed_fleets_have_no_autoscale_surface() {
    let clock = Clock::manual();
    let cfg = config(Some(bounds(2, 6, 3)), &clock, 64, ShedPolicy::Block);
    let mut b = GatewayBuilder::with_config(cfg);
    b.register("t", engine("t"));
    let gw = b.start();
    assert_eq!(gw.scale_to(0), 1, "floor of one live worker");
    assert_eq!(gw.scale_to(100), 6, "ceiling of worker_slots");
    assert_eq!(gw.scale_to(3), 3);
    assert_eq!(gw.active_workers(), 3);
    assert!(gw.shutdown().conserved());

    let clock = Clock::manual();
    let mut b = GatewayBuilder::with_config(config(None, &clock, 64, ShedPolicy::Block));
    b.register("t", engine("t"));
    let gw = b.start();
    assert_eq!(gw.active_workers(), 2, "fixed fleets run `replicas` workers");
    assert_eq!(gw.worker_slots(), 2);
    assert!(gw.autoscale_apply(&breach()).is_none(), "no policy, no scaling");
    assert!(gw.autoscale_tick().is_none());
    assert!(gw.scale_events().is_empty());
    assert!(gw.shutdown().conserved());
}

/// Regression: draining an *idle* fleet must not lose the stop wakeup.
/// An idle worker parks on an untimed wait; flagging it as stopping
/// without ordering the store+notify against that park (via the state
/// mutex) could land mid-iteration and leave the victim parked forever,
/// wedging the join — and, through it, shutdown. Oscillating through
/// many spawn-then-immediately-drain cycles maximizes the window; every
/// join must return promptly and the survivor must still serve.
#[test]
fn idle_fleet_scale_oscillation_never_wedges() {
    let clock = Clock::manual();
    let cfg = config(Some(bounds(1, 6, 3)), &clock, 64, ShedPolicy::Block);
    let mut b = GatewayBuilder::with_config(cfg);
    let id = b.register("t", engine("t"));
    let gw = b.start();
    for round in 0..50 {
        assert_eq!(gw.scale_to(6), 6, "scale-up stuck at round {round}");
        assert_eq!(gw.scale_to(1), 1, "drain stuck at round {round}");
    }
    assert_eq!(gw.handle(id).infer_q(vec![1; 8]).unwrap().t.len(), 10);
    assert!(gw.shutdown().conserved());
}

/// The worker-seconds ledger on the manual clock: a clock advance grows
/// `worker_time_us` by at least one full span (a proven-live worker)
/// and at most `active x advance`; joining a drained victim moves its
/// running span into the accumulator without changing the total.
#[test]
fn worker_time_ledger_is_conserved_across_drains() {
    let clock = Clock::manual();
    let cfg = config(Some(bounds(2, 4, 3)), &clock, 64, ShedPolicy::Block);
    let mut b = GatewayBuilder::with_config(cfg);
    let id = b.register("t", engine("t"));
    let gw = b.start();
    // a completed request proves at least one worker is live and has
    // stamped its start time (stamping happens before any serving)
    assert_eq!(gw.handle(id).infer_q(vec![1; 8]).unwrap().t.len(), 10);

    let t1 = gw.worker_time_us();
    clock.advance(Duration::from_micros(1_000));
    let t2 = gw.worker_time_us();
    let delta = t2 - t1;
    assert!(
        (1_000..=2_000).contains(&delta),
        "2 active workers over a 1000us advance must bank 1000..=2000 worker-us, got {delta}"
    );

    // drain to one: the victim's running span moves into the exited
    // accumulator; with time frozen the total is exactly unchanged
    assert_eq!(gw.scale_to(1), 1);
    assert_eq!(gw.worker_time_us(), t2, "a drain conserves banked worker-time");
    assert_eq!(gw.active_workers(), 1);

    // only the surviving slot can serve now, so a completed request
    // proves it is stamped; with one live worker the ledger then grows
    // by exactly the advance
    assert_eq!(gw.handle(id).infer_q(vec![2; 8]).unwrap().t.len(), 10);
    clock.advance(Duration::from_micros(500));
    let t3 = gw.worker_time_us();
    assert_eq!(t3, t2 + 500, "one live worker banks exactly the advance");
    assert!(gw.shutdown().conserved());
}

/// The drain contract under fire: scale-downs race two `DropOldest`
/// floods and add/remove model churn, and per-model conservation
/// (`submitted == completed + shed + failed`) holds for every tenant —
/// live, removed, and churned — with the gateway and the clients
/// agreeing on every completion.
#[test]
fn scale_down_drain_conserves_counters_under_churn_and_flood() {
    let clock = Clock::manual();
    // calm_windows: 1 makes every calm evaluation drain one worker, so
    // the test exercises the maximum scaling churn per applied signal
    let cfg = config(Some(bounds(1, 4, 1)), &clock, 32, ShedPolicy::DropOldest);
    let mut b = GatewayBuilder::with_config(cfg);
    let anchor = b.register("anchor", engine("anchor"));
    let gw = b.start();
    gw.autoscale_apply(&breach()); // 1 -> 2
    gw.autoscale_apply(&breach()); // 2 -> 4
    assert_eq!(gw.active_workers(), 4);

    let mut flood_ok = 0u64;
    std::thread::scope(|s| {
        let mut floods = Vec::new();
        for seed in [0u8, 7] {
            let h = gw.handle(anchor);
            floods.push(s.spawn(move || {
                let mut ok = 0u64;
                let mut tickets = Vec::new();
                for i in 0..300u16 {
                    match h.submit_q(vec![(i as u8).wrapping_add(seed); 8]) {
                        Ok(t) => tickets.push(t),
                        Err(ServeError::QueueFull) => {}
                        Err(e) => panic!("unexpected submit error {e}"),
                    }
                }
                for t in tickets {
                    match t.wait() {
                        Ok(_) => ok += 1,
                        Err(ServeError::QueueFull) => {} // DropOldest eviction
                        Err(e) => panic!("unexpected ticket outcome {e}"),
                    }
                }
                ok
            }));
        }
        // registry churn riding alongside the floods: tenants come and
        // go while the fleet is scaling underneath them
        let churner = s.spawn(|| {
            for i in 0..8u32 {
                let name = format!("churn{i}");
                let h = gw.add_model(&name, engine(&name)).unwrap();
                let mut tickets = Vec::new();
                for j in 0..20u8 {
                    match h.submit_q(vec![j; 8]) {
                        Ok(t) => tickets.push(t),
                        Err(ServeError::QueueFull) => {}
                        Err(e) => panic!("unexpected submit error {e}"),
                    }
                }
                let mode = if i % 2 == 0 { DrainMode::Serve } else { DrainMode::Shed };
                let removed = gw.remove_model(h.model_id(), mode).unwrap();
                assert!(removed.conserved(), "{removed:?}");
                for t in tickets {
                    match t.wait() {
                        Ok(_) | Err(ServeError::QueueFull) => {}
                        Err(e) => panic!("unexpected ticket outcome {e}"),
                    }
                }
            }
        });
        // scaling churn on the main thread: each calm application
        // synchronously drains (and joins) a victim mid-flood, each
        // breach re-spawns — the drain contract under live traffic
        for _ in 0..6 {
            gw.autoscale_apply(&calm());
            gw.autoscale_apply(&breach());
        }
        while gw.active_workers() > 1 {
            gw.autoscale_apply(&calm());
        }
        for f in floods {
            flood_ok += f.join().unwrap();
        }
        churner.join().unwrap();
    });
    assert_eq!(gw.active_workers(), 1);
    assert!(!gw.scale_events().is_empty());

    let stats = gw.shutdown();
    assert!(stats.conserved(), "{stats:?}");
    let a = &stats.per_model[anchor.index()];
    assert_eq!(a.submitted, 600, "every flood submission is accounted");
    assert_eq!(a.completed, flood_ok, "gateway and clients agree on completions");
    assert_eq!(a.submitted, a.completed + a.shed + a.failed);
}
