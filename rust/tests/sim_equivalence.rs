//! Property tests binding the closed-form simulator to the event-exact
//! engine: cycles and active slots must match *exactly*; useful MACs
//! match up to LUT-quantization zeros (cycle <= analytic, within a small
//! relative band).

use kan_sas::arch::{ArrayConfig, WeightLoad};
use kan_sas::sim::workload::Workload;
use kan_sas::sim::{analytic, cycle, synth};
use kan_sas::util::rng::{check, Rng};

#[test]
fn conventional_cycles_and_slots_match_exactly() {
    check(40, 101, |rng: &mut Rng| {
        let g = 1 + rng.below(8);
        let p = 1 + rng.below(3);
        let bs = 1 + rng.below(12);
        let k_feats = 1 + rng.below(8);
        let n_out = 1 + rng.below(10);
        let wl = Workload::kan("w", bs, k_feats, n_out, g, p);
        let mut cfg = ArrayConfig::conventional(1 + rng.below(8), 1 + rng.below(8));
        if rng.below(2) == 0 {
            cfg.weight_load = WeightLoad::Counted;
        }
        let a = analytic::simulate(&cfg, &wl);
        let (_vals, _ks, dense) = synth::kan_activations(bs, k_feats, g, p, rng);
        let w = synth::weights(k_feats * (g + p), n_out, rng);
        let c = cycle::run_conventional(&cfg, &dense, &w);
        assert_eq!(a.cycles, c.stats.cycles, "cycles {} {:?}", cfg.label(), wl);
        assert_eq!(a.active_slots, c.stats.active_slots, "slots");
        assert_eq!(a.tiles, c.stats.tiles, "tiles");
        // useful: analytic assumes every window value non-zero; the LUT
        // introduces a few true zeros
        assert!(c.stats.useful_macs <= a.useful_macs);
        assert!(
            c.stats.useful_macs as f64 >= 0.75 * a.useful_macs as f64,
            "useful {} vs analytic {}",
            c.stats.useful_macs,
            a.useful_macs
        );
    });
}

#[test]
fn kansas_cycles_and_slots_match_exactly() {
    check(40, 102, |rng: &mut Rng| {
        let g = 1 + rng.below(8);
        let p = 1 + rng.below(3);
        let bs = 1 + rng.below(12);
        let k_feats = 1 + rng.below(8);
        let n_out = 1 + rng.below(10);
        let wl = Workload::kan("w", bs, k_feats, n_out, g, p);
        let mut cfg = ArrayConfig::kan_sas(1 + rng.below(6), 1 + rng.below(6), p + 1, g + p);
        if rng.below(2) == 0 {
            cfg.weight_load = WeightLoad::Counted;
        }
        let a = analytic::simulate(&cfg, &wl);
        let (vals, ks, _dense) = synth::kan_activations(bs, k_feats, g, p, rng);
        let coeff = synth::coefficients(k_feats, g + p, n_out, rng);
        let c = cycle::run_kansas_kan(&cfg, &vals, &ks, &coeff);
        assert_eq!(a.cycles, c.stats.cycles, "cycles {}", cfg.label());
        assert_eq!(a.active_slots, c.stats.active_slots, "slots");
        assert_eq!(a.tiles, c.stats.tiles, "tiles");
        assert!(c.stats.useful_macs <= a.useful_macs);
    });
}

#[test]
fn dense_on_vector_matches_exactly_including_useful() {
    // dense activations are generated with no zeros, so useful MACs must
    // match the analytic expectation *exactly*
    check(40, 103, |rng: &mut Rng| {
        let bs = 1 + rng.below(12);
        let k_feats = 1 + rng.below(40);
        let n_out = 1 + rng.below(10);
        let wl = Workload::dense("d", bs, k_feats, n_out);
        let n_pe = 1 + rng.below(4);
        let cfg = ArrayConfig::kan_sas(1 + rng.below(6), 1 + rng.below(6), n_pe, n_pe + rng.below(6));
        let a = analytic::simulate(&cfg, &wl);
        let act = synth::dense_activations(bs, k_feats, rng);
        let w = synth::weights(k_feats, n_out, rng);
        let c = cycle::run_kansas_dense(&cfg, &act, &w);
        assert_eq!(a.cycles, c.stats.cycles, "cycles {}", cfg.label());
        assert_eq!(a.active_slots, c.stats.active_slots, "slots");
        assert_eq!(a.useful_macs, c.stats.useful_macs, "useful");
    });
}

#[test]
fn equal_area_cycle_advantage_holds_on_cycle_engine() {
    // Fig. 7b's headline (~2x at equal area) reproduced by the event-exact
    // engine on a medium workload, not just the closed form
    let (g, p) = (5usize, 3usize);
    let mut rng = Rng::new(7);
    let bs = 64;
    let k_feats = 48;
    let n_out = 32;
    let (vals, ks, dense) = synth::kan_activations(bs, k_feats, g, p, &mut rng);
    let coeff = synth::coefficients(k_feats, g + p, n_out, &mut rng);
    let flat = synth::flatten_coeff(&coeff);

    let conv = ArrayConfig::conventional(32, 32); // ~0.50 mm^2
    let kan = ArrayConfig::kan_sas(16, 16, 4, 8); // ~0.47 mm^2
    let c = cycle::run_conventional(&conv, &dense, &flat);
    let k = cycle::run_kansas_kan(&kan, &vals, &ks, &coeff);
    assert_eq!(c.out, k.out, "both arrays compute the same GEMM");
    let ratio = c.stats.cycles as f64 / k.stats.cycles as f64;
    assert!(ratio > 1.5, "equal-area cycle ratio {ratio} (paper ~2x)");
}
