//! Bench-artifact hygiene: `BENCH_engine.json` / `BENCH_serving.json`
//! are the machine-readable perf trail tracked across PRs, and
//! `TELEMETRY.jsonl` is the serving observability stream — all written
//! by the deterministic `util::json` renderer. This smoke test pins
//! three things: (1) documents with the serving bench's and telemetry
//! stream's schemas survive a render → parse → render round trip
//! unchanged (the renderer is a fixpoint, so diffs between PRs are
//! semantic, not formatting noise), (2) any artifact already sitting in
//! the working tree actually parses — a bench that starts emitting
//! invalid JSON fails here, not in whatever downstream tooling reads
//! the trail — and (3) a live `kansas serve --telemetry` stream (e.g.
//! the CI smoke step's) holds one valid object per line, each tagged
//! with a known `kind`.

use kan_sas::bench::{write_artifact, SCHEMA_VERSION};
use kan_sas::util::json::Value;

/// A miniature of the `serving_scale` output: one row per section,
/// including the PR-5 `quota` rows and the demand-normalized fairness
/// field.
fn serving_schema_doc() -> Value {
    Value::obj([
        ("bench", Value::str("serving_scale")),
        ("model", Value::str("bench_kan")),
        ("cores", Value::num(4.0)),
        (
            "closed_loop",
            Value::arr([Value::obj([
                ("replicas", Value::num(2.0)),
                ("rows_per_s", Value::num(12345.6)),
                ("p99_us", Value::num(890.0)),
            ])]),
        ),
        (
            "fairness",
            Value::arr([Value::obj([
                ("dispatch", Value::str("fair-steal")),
                ("fairness_index", Value::num(0.93)),
                ("fairness_normalized", Value::num(0.99)),
                ("minority_p95_queue_us", Value::num(410.0)),
            ])]),
        ),
        (
            "quota",
            Value::arr([Value::obj([
                ("quota", Value::str("on")),
                ("minority_shed_rate", Value::num(0.02)),
                ("majority_shed_rate", Value::num(0.31)),
                ("registry_epoch", Value::num(1.0)),
                (
                    "per_model",
                    Value::arr([Value::obj([
                        ("model", Value::str("minority")),
                        ("reserved_slots", Value::num(51.0)),
                        ("conserved", Value::num(1.0)),
                    ])]),
                ),
            ])]),
        ),
    ])
}

#[test]
fn serving_bench_schema_roundtrips_deterministically() {
    let doc = serving_schema_doc();
    let text = doc.render();
    let parsed = Value::parse(&text).expect("the renderer must emit valid JSON");
    assert_eq!(parsed.render(), text, "render → parse → render is a fixpoint");
    // spot-check a nested path survives
    let shed = parsed
        .path("quota/0/minority_shed_rate")
        .and_then(Value::as_f64)
        .expect("nested quota row readable");
    assert!((shed - 0.02).abs() < 1e-12);
}

#[test]
fn bench_artifacts_on_disk_stay_valid_json() {
    for name in ["BENCH_serving.json", "BENCH_engine.json"] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(name);
        let Ok(text) = std::fs::read_to_string(&path) else {
            continue; // benches not run in this tree; nothing to check
        };
        let v = Value::parse(&text)
            .unwrap_or_else(|e| panic!("{name} is not valid JSON: {e}"));
        assert!(v.get("bench").is_some(), "{name} is missing its 'bench' tag");
        assert_eq!(
            v.get("schema_version").and_then(Value::as_f64),
            Some(SCHEMA_VERSION as f64),
            "{name} carries a stale or missing schema_version (rerun the bench)"
        );
    }
}

/// `write_artifact` stamps the schema version on every write — including
/// merge-appends over an existing artifact that predates the stamp.
#[test]
fn write_artifact_stamps_schema_version() {
    let path =
        std::env::temp_dir().join(format!("kan_sas_schema_stamp_{}.json", std::process::id()));
    let path = path.to_str().expect("utf-8 temp path").to_string();
    // simulate a pre-versioning artifact already on disk
    std::fs::write(&path, "{\"bench\": \"engine\", \"old\": [1]}\n").expect("seed artifact");
    write_artifact(&path, Value::obj([("fresh", Value::num(2.0))])).expect("merge write");
    let text = std::fs::read_to_string(&path).expect("artifact readable");
    std::fs::remove_file(&path).ok();
    let v = Value::parse(&text).expect("artifact is valid JSON");
    assert_eq!(v.get("schema_version").and_then(Value::as_f64), Some(SCHEMA_VERSION as f64));
    assert_eq!(v.path("old/0").and_then(Value::as_f64), Some(1.0), "merge still appends");
    assert_eq!(v.get("fresh").and_then(Value::as_f64), Some(2.0));
}

/// A miniature of the `kansas serve --telemetry` stream: one line of
/// each kind the spine emits (window snapshot, trace span, flight dump).
fn telemetry_schema_lines() -> Vec<Value> {
    vec![
        Value::obj([
            ("kind", Value::str("window")),
            ("at_us", Value::num(1_000_000.0)),
            ("dropped_events", Value::num(0.0)),
            (
                "tenants",
                Value::arr([Value::obj([
                    ("name", Value::str("mnist")),
                    ("live", Value::Bool(true)),
                    (
                        "window",
                        Value::obj([
                            ("throughput_rps", Value::num(1234.5)),
                            ("shed_rate", Value::num(0.01)),
                            ("sim_utilization", Value::num(0.62)),
                            (
                                "queue",
                                Value::obj([
                                    ("p50_us", Value::num(80.0)),
                                    ("p95_us", Value::num(410.0)),
                                ]),
                            ),
                            ("service", Value::Null),
                        ]),
                    ),
                    (
                        "totals",
                        Value::obj([
                            ("admitted", Value::num(640.0)),
                            ("completed", Value::num(612.0)),
                            ("shed", Value::num(28.0)),
                        ]),
                    ),
                ])]),
            ),
        ]),
        Value::obj([
            ("kind", Value::str("span")),
            ("trace", Value::num(65.0)),
            ("tenant", Value::str("mnist")),
            ("admitted_us", Value::num(5000.0)),
            ("enqueued_us", Value::num(5100.0)),
            ("batch_us", Value::Null),
            ("stolen", Value::Bool(false)),
            ("responded_us", Value::num(6400.0)),
            ("queue_us", Value::num(900.0)),
            ("service_us", Value::num(500.0)),
            ("worker", Value::num(1.0)),
        ]),
        Value::obj([
            ("kind", Value::str("flight")),
            ("at_us", Value::num(2_000_000.0)),
            ("churn_dropped", Value::num(0.0)),
            (
                "churn",
                Value::arr([Value::obj([
                    ("t_us", Value::num(12.0)),
                    ("action", Value::str("registered")),
                    ("tenant", Value::str("mnist")),
                    ("weight", Value::num(1.0)),
                    ("epoch", Value::num(1.0)),
                ])]),
            ),
            (
                "tenants",
                Value::arr([Value::obj([
                    ("name", Value::str("mnist")),
                    (
                        "events",
                        Value::arr([Value::obj([
                            ("t_us", Value::num(5000.0)),
                            ("event", Value::str("admitted")),
                            ("rows", Value::num(1.0)),
                            ("worker", Value::num(2.0)),
                        ])]),
                    ),
                ])]),
            ),
        ]),
    ]
}

#[test]
fn telemetry_jsonl_schema_roundtrips_deterministically() {
    for line in telemetry_schema_lines() {
        let text = line.render();
        assert!(!text.contains('\n'), "JSONL lines must be single-line");
        let parsed = Value::parse(&text).expect("the renderer must emit valid JSON");
        assert_eq!(parsed.render(), text, "render → parse → render is a fixpoint");
    }
}

#[test]
fn telemetry_stream_on_disk_stays_valid_jsonl() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("TELEMETRY.jsonl");
    let Ok(text) = std::fs::read_to_string(&path) else {
        return; // no serve --telemetry run in this tree; nothing to check
    };
    let mut lines = 0usize;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Value::parse(line)
            .unwrap_or_else(|e| panic!("TELEMETRY.jsonl line {}: invalid JSON: {e}", i + 1));
        assert_eq!(v.render(), line, "TELEMETRY.jsonl line {} is not renderer-canonical", i + 1);
        let kind = v.get("kind").and_then(Value::as_str).unwrap_or_else(|| {
            panic!("TELEMETRY.jsonl line {} has no string 'kind' tag", i + 1)
        });
        assert!(
            matches!(kind, "window" | "span" | "flight"),
            "TELEMETRY.jsonl line {}: unknown kind '{kind}'",
            i + 1
        );
        lines += 1;
    }
    assert!(lines > 0, "a present TELEMETRY.jsonl must hold at least one record");
}
