//! Bench-artifact hygiene: `BENCH_engine.json` / `BENCH_serving.json`
//! are the machine-readable perf trail tracked across PRs, written by
//! the deterministic `util::json` renderer. This smoke test pins two
//! things: (1) a document with the serving bench's schema survives a
//! render → parse → render round trip unchanged (the renderer is a
//! fixpoint, so diffs between PRs are semantic, not formatting noise),
//! and (2) any artifact already sitting in the working tree actually
//! parses — a bench that starts emitting invalid JSON fails here, not
//! in whatever downstream tooling reads the trail.

use kan_sas::util::json::Value;

/// A miniature of the `serving_scale` output: one row per section,
/// including the PR-5 `quota` rows and the demand-normalized fairness
/// field.
fn serving_schema_doc() -> Value {
    Value::obj([
        ("bench", Value::str("serving_scale")),
        ("model", Value::str("bench_kan")),
        ("cores", Value::num(4.0)),
        (
            "closed_loop",
            Value::arr([Value::obj([
                ("replicas", Value::num(2.0)),
                ("rows_per_s", Value::num(12345.6)),
                ("p99_us", Value::num(890.0)),
            ])]),
        ),
        (
            "fairness",
            Value::arr([Value::obj([
                ("dispatch", Value::str("fair-steal")),
                ("fairness_index", Value::num(0.93)),
                ("fairness_normalized", Value::num(0.99)),
                ("minority_p95_queue_us", Value::num(410.0)),
            ])]),
        ),
        (
            "quota",
            Value::arr([Value::obj([
                ("quota", Value::str("on")),
                ("minority_shed_rate", Value::num(0.02)),
                ("majority_shed_rate", Value::num(0.31)),
                ("registry_epoch", Value::num(1.0)),
                (
                    "per_model",
                    Value::arr([Value::obj([
                        ("model", Value::str("minority")),
                        ("reserved_slots", Value::num(51.0)),
                        ("conserved", Value::num(1.0)),
                    ])]),
                ),
            ])]),
        ),
    ])
}

#[test]
fn serving_bench_schema_roundtrips_deterministically() {
    let doc = serving_schema_doc();
    let text = doc.render();
    let parsed = Value::parse(&text).expect("the renderer must emit valid JSON");
    assert_eq!(parsed.render(), text, "render → parse → render is a fixpoint");
    // spot-check a nested path survives
    let shed = parsed
        .path("quota/0/minority_shed_rate")
        .and_then(Value::as_f64)
        .expect("nested quota row readable");
    assert!((shed - 0.02).abs() < 1e-12);
}

#[test]
fn bench_artifacts_on_disk_stay_valid_json() {
    for name in ["BENCH_serving.json", "BENCH_engine.json"] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(name);
        let Ok(text) = std::fs::read_to_string(&path) else {
            continue; // benches not run in this tree; nothing to check
        };
        let v = Value::parse(&text)
            .unwrap_or_else(|e| panic!("{name} is not valid JSON: {e}"));
        assert!(v.get("bench").is_some(), "{name} is missing its 'bench' tag");
    }
}
