//! Property fuzz over the framed wire protocol: random, truncated, and
//! bit-flipped byte streams against [`FrameHeader::decode`] and against
//! a live loopback [`NetServer`]. The decoder must never panic and must
//! type every rejection; the connection state machine must answer
//! survivable corruption with a `MALFORMED` error frame and keep
//! serving, and must shrug off streams that die mid-frame.
//!
//! Every randomized test derives its seed from `KANSAS_SEED` (the CI
//! stress matrix pins it) and prints it, so any failure names its
//! exact replay.

mod common;

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use kan_sas::arch::ArrayConfig;
use kan_sas::coordinator::net::{
    code, decode_ok_payload, encode_request, FrameError, FrameHeader, FrameType, HEADER_LEN,
    MAGIC, VERSION,
};
use kan_sas::coordinator::{
    BatchPolicy, Dispatch, Gateway, GatewayBuilder, GatewayConfig, NetClient, NetConfig, NetServer,
    QuotaPolicy, ShedPolicy, TelemetryConfig,
};
use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::util::rng::{check, Rng};

fn gateway() -> Gateway {
    let mut b = GatewayBuilder::with_config(GatewayConfig {
        replicas: 1,
        queue_cap: 256,
        shed: ShedPolicy::RejectNew,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        dispatch: Dispatch::FairSteal,
        quota: QuotaPolicy::None,
        telemetry: TelemetryConfig::default(),
        ..Default::default()
    });
    b.register("fuzz", Engine::new(QuantizedModel::synthetic("fuzz", &[8, 12, 10], 5, 3, 31)));
    b.start()
}

fn read_frame(stream: &mut TcpStream) -> Option<(FrameHeader, Vec<u8>)> {
    let mut hdr = [0u8; HEADER_LEN];
    stream.read_exact(&mut hdr).ok()?;
    let h = FrameHeader::decode(&hdr).expect("server frames are well-formed");
    let mut payload = vec![0u8; h.len as usize];
    stream.read_exact(&mut payload).ok()?;
    Some((h, payload))
}

/// Random 32-byte buffers: decode either accepts a genuinely
/// well-formed header (and re-encodes it byte-identically, modulo the
/// reserved byte) or returns the typed error matching the first bad
/// field in validation order — never a panic.
#[test]
fn header_decode_never_panics_on_random_bytes() {
    let seed = common::base_seed(0xF0A2);
    println!("net_fuzz seed {seed}");
    check(4_000, seed, |rng| {
        let mut buf = [0u8; HEADER_LEN];
        for b in buf.iter_mut() {
            *b = rng.next_u64() as u8;
        }
        // bias some cases toward the deeper checks: random magic bytes
        // almost never spell KSN1 on their own
        match rng.below(4) {
            0 => {}
            1 => buf[0..4].copy_from_slice(&MAGIC),
            _ => {
                buf[0..4].copy_from_slice(&MAGIC);
                buf[4] = VERSION;
            }
        }
        match FrameHeader::decode(&buf) {
            Ok(h) => {
                assert_eq!(buf[0..4], MAGIC);
                assert_eq!(buf[4], VERSION);
                let mut re = [0u8; HEADER_LEN];
                h.encode(&mut re);
                assert_eq!(re[0..7], buf[0..7], "accepted headers round-trip");
                assert_eq!(re[8..], buf[8..], "accepted headers round-trip");
            }
            Err(FrameError::BadMagic(m)) => {
                assert_eq!(m, [buf[0], buf[1], buf[2], buf[3]]);
            }
            Err(FrameError::BadVersion(v)) => {
                assert_eq!(buf[0..4], MAGIC);
                assert_eq!(v, buf[4]);
            }
            Err(FrameError::BadType(t)) => {
                assert_eq!(buf[0..4], MAGIC);
                assert_eq!(buf[4], VERSION);
                assert_eq!(t, buf[5]);
            }
        }
    });
}

/// Single-bit corruption of a valid header: the decode outcome is fully
/// determined by which byte the flip landed in, and a flip is never
/// silently absorbed except in the reserved byte.
#[test]
fn single_bit_flips_decode_deterministically() {
    const TYPES: [FrameType; 7] = [
        FrameType::InferRequest,
        FrameType::InferOk,
        FrameType::Error,
        FrameType::StatsRequest,
        FrameType::StatsResponse,
        FrameType::ModelsRequest,
        FrameType::ModelsResponse,
    ];
    let seed = common::base_seed(0xB17F);
    println!("net_fuzz seed {seed}");
    check(4_000, seed, |rng| {
        let h = FrameHeader {
            ty: TYPES[rng.below(TYPES.len())],
            code: rng.next_u64() as u8,
            corr: rng.next_u64(),
            model: rng.next_u64() as u32,
            deadline_us: rng.next_u64(),
            len: rng.next_u64() as u32,
        };
        let mut buf = [0u8; HEADER_LEN];
        h.encode(&mut buf);
        assert_eq!(FrameHeader::decode(&buf).unwrap(), h, "clean round-trip");
        let bit = rng.below(HEADER_LEN * 8);
        let byte = bit / 8;
        buf[byte] ^= 1 << (bit % 8);
        match FrameHeader::decode(&buf) {
            Err(FrameError::BadMagic(_)) => assert!(byte < 4, "magic lives in bytes 0..4"),
            Err(FrameError::BadVersion(_)) => assert_eq!(byte, 4),
            Err(FrameError::BadType(_)) => assert_eq!(byte, 5),
            Ok(h2) => {
                assert!(byte >= 5, "flips in magic/version can never decode");
                if byte == 7 {
                    assert_eq!(h2, h, "the reserved byte is ignored");
                } else {
                    assert_ne!(h2, h, "a flip outside the reserved byte must be visible");
                }
            }
        }
    });
}

/// [`decode_ok_payload`] on random payload lengths and bytes: accepts
/// exactly `16 + 8k` byte payloads, rejects everything else with a
/// typed error, and never panics.
#[test]
fn ok_payload_decode_never_panics() {
    let seed = common::base_seed(0x9E37);
    println!("net_fuzz seed {seed}");
    check(2_000, seed, |rng| {
        let n = rng.below(120);
        let payload: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let mut t = Vec::new();
        match decode_ok_payload(&payload, &mut t) {
            Ok(_) => {
                assert!(n >= 16 && (n - 16) % 8 == 0);
                assert_eq!(t.len(), (n - 16) / 8);
            }
            Err(_) => assert!(n < 16 || (n - 16) % 8 != 0),
        }
    });
}

/// Survivable corruption on a live connection: flip one bit somewhere
/// in the magic/version/type bytes of a well-formed request, send it,
/// then send a clean request on the same socket. Every round must
/// answer a typed `MALFORMED` error (echoing the corrupted frame's
/// correlation id — the id bytes are untouched) followed by a real
/// `InferOk`, proving the reader resynced instead of dying.
#[test]
fn corrupted_headers_get_typed_errors_and_the_connection_survives() {
    let seed = common::base_seed(0xC0DE);
    println!("net_fuzz seed {seed}");
    let gw = gateway();
    let server = NetServer::start("127.0.0.1:0", &gw, NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let mut rng = Rng::new(seed);

    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = Vec::new();
    for round in 0..20u64 {
        let bad_corr = rng.next_u64();
        let row: Vec<u8> = (0..8).map(|_| rng.next_u64() as u8).collect();
        encode_request(&mut buf, bad_corr, 0, &row, 0, 0);
        // corrupt magic, version, or type — for an InferRequest any
        // single-bit flip here is survivable (the length field stays
        // trusted, so the reader can skip the payload and resync)
        let bit = rng.below(6 * 8);
        buf[bit / 8] ^= 1 << (bit % 8);
        s.write_all(&buf).unwrap();

        let good_corr = rng.next_u64();
        encode_request(&mut buf, good_corr, 0, &row, 0, 0);
        s.write_all(&buf).unwrap();

        let (h1, p1) = read_frame(&mut s).expect("error frame for the corrupted request");
        assert_eq!(h1.ty, FrameType::Error, "round {round}");
        assert_eq!(h1.code, code::MALFORMED, "round {round}");
        assert_eq!(h1.corr, bad_corr, "corr bytes were untouched, round {round}");
        assert!(!p1.is_empty(), "the error message names the defect");

        let (h2, p2) = read_frame(&mut s).expect("the clean request is served");
        assert_eq!(h2.ty, FrameType::InferOk, "round {round}");
        assert_eq!(h2.corr, good_corr, "round {round}");
        let mut t = Vec::new();
        decode_ok_payload(&p2, &mut t).unwrap();
        assert_eq!(t.len(), 10, "round {round}");
    }
    drop(s);

    let stats = server.shutdown();
    assert_eq!(stats.malformed, 20, "one typed rejection per corrupted frame");
    assert!(gw.shutdown().conserved());
}

/// Hostile streams — pure random bytes, frames truncated mid-header and
/// mid-payload, and an untrusted oversized length — must never take the
/// server down: after all of them, a fresh well-formed client still
/// lists models and serves an inference.
#[test]
fn garbage_and_truncated_streams_never_kill_the_server() {
    let seed = common::base_seed(0x6A5B);
    println!("net_fuzz seed {seed}");
    let gw = gateway();
    let server = NetServer::start("127.0.0.1:0", &gw, NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let mut rng = Rng::new(seed);

    // pure random byte streams of random lengths, then hangup
    for _ in 0..16 {
        let mut s = TcpStream::connect(&addr).unwrap();
        let n = rng.below(200);
        let junk: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let _ = s.write_all(&junk);
    }
    // a frame truncated mid-header
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut buf = Vec::new();
        encode_request(&mut buf, 1, 0, &[1u8; 8], 0, 0);
        let _ = s.write_all(&buf[..HEADER_LEN / 2]);
    }
    // a valid header whose payload dies early
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut buf = Vec::new();
        encode_request(&mut buf, 2, 0, &[2u8; 8], 0, 0);
        let _ = s.write_all(&buf[..HEADER_LEN + 3]);
    }
    // bad magic with an untrusted oversized length: the server answers
    // and closes, because framing can no longer be resynced
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let _ = s.write_all(&[0xFFu8; HEADER_LEN]);
    }

    // the server is still alive and serving
    let client = NetClient::connect(&addr).unwrap();
    let h = client.handle("fuzz").unwrap();
    let r = h.infer_q(vec![3; 8]).unwrap();
    assert_eq!(r.t.len(), 10);
    client.close();

    let stats = server.shutdown();
    assert!(stats.malformed >= 1, "the all-0xFF header is always counted: {stats:?}");
    assert!(stats.accepted >= 20, "every hostile connection was accepted: {stats:?}");
    assert!(gw.shutdown().conserved());
}
