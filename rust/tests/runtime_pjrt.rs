//! PJRT round-trip: load the AOT HLO text, compile on the CPU client,
//! execute with the exported weights, and cross-check against both the
//! golden labels and the integer engine. Artifact-gated, and compiled
//! only with the `xla` feature (the default offline build has no PJRT).
#![cfg(feature = "xla")]

use std::path::PathBuf;

use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::quant;
use kan_sas::runtime::{FloatEngine, ModelArtifacts};
use kan_sas::util::container::Container;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    artifacts().join(name).exists()
}

#[test]
fn quickstart_hlo_executes() {
    if !have("quickstart_kan.kwts") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let client = xla::PjRtClient::cpu().expect("pjrt cpu client");
    let art = ModelArtifacts::new(&artifacts(), "quickstart_kan");
    let batches = art.available_batches().unwrap();
    assert!(batches.contains(&1), "batches {batches:?}");
    let engine = FloatEngine::load(&client, &art, 1).expect("compile hlo");
    assert_eq!(engine.in_dim, 4);
    assert_eq!(engine.out_dim, 3);
    let logits = engine.execute(&[0.1, -0.4, 0.3, 0.7]).unwrap();
    assert_eq!(logits.len(), 3);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn fp32_and_int8_engines_agree_on_golden_batch() {
    // the PJRT fp32 path and the integer engine must agree on almost all
    // predictions (they differ only by quantization error, which the
    // paper bounds at <1% accuracy)
    if !have("quickstart_kan.kwts") || !have("quickstart_kan_golden.kgld") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let client = xla::PjRtClient::cpu().unwrap();
    let art = ModelArtifacts::new(&artifacts(), "quickstart_kan");
    let fe = FloatEngine::load(&client, &art, 32).unwrap();

    let golden = Container::open(&artifacts().join("quickstart_kan_golden.kgld")).unwrap();
    let (x_q, xs) = golden.u8("x_q").unwrap();
    let bs = 32.min(xs[0]);
    let in_dim = xs[1];
    let x: Vec<f32> = x_q[..bs * in_dim].iter().map(|&q| quant::dequantize_activation(q)).collect();

    let logits = fe.execute(&x).unwrap();
    let fp_preds = fe.predictions(&logits);

    let qm = QuantizedModel::load(&artifacts().join("quickstart_kan.kanq")).unwrap();
    let ie = Engine::new(qm);
    let int_preds = ie.forward_from_q(&x_q[..bs * in_dim], bs).unwrap().predictions();

    let agree = fp_preds.iter().zip(&int_preds).filter(|(a, b)| a == b).count();
    assert!(
        agree as f64 / bs as f64 >= 0.9,
        "fp32/int8 prediction agreement {agree}/{bs}"
    );
}

#[test]
fn mnist_hlo_batch128_executes() {
    if !have("mnist_kan.kwts") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let client = xla::PjRtClient::cpu().unwrap();
    let art = ModelArtifacts::new(&artifacts(), "mnist_kan");
    let fe = FloatEngine::load(&client, &art, 128).unwrap();
    let x = vec![0.0f32; 128 * 784];
    let logits = fe.execute(&x).unwrap();
    assert_eq!(logits.len(), 128 * 10);
    // all rows identical for identical inputs
    let first = &logits[..10];
    for row in logits.chunks_exact(10).skip(1) {
        for (a, b) in row.iter().zip(first) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}

#[test]
fn wrong_batch_size_rejected() {
    if !have("quickstart_kan.kwts") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let client = xla::PjRtClient::cpu().unwrap();
    let art = ModelArtifacts::new(&artifacts(), "quickstart_kan");
    let fe = FloatEngine::load(&client, &art, 1).unwrap();
    assert!(fe.execute(&[0.0; 8]).is_err()); // 2 rows into a b1 module
    assert!(FloatEngine::load(&client, &art, 999).is_err()); // no such module
}
