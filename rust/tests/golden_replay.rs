//! Cross-language bit-exactness: replay the golden vectors exported by
//! `python/compile/aot.py` through the rust integer engine and require
//! *exact* equality at every recorded point (unit outputs, per-layer
//! activations, final accumulators, predictions).
//!
//! These tests are artifact-gated: they skip (with a notice) when
//! `make artifacts` has not run.

use std::path::PathBuf;

use kan_sas::bspline::BsplineUnit;
use kan_sas::kan::{Engine, Kernel, QuantizedModel, Scratch};
use kan_sas::quant;
use kan_sas::util::container::Container;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn open_pair(name: &str) -> Option<(QuantizedModel, Container)> {
    let kanq = artifacts().join(format!("{name}.kanq"));
    let gold = artifacts().join(format!("{name}_golden.kgld"));
    if !kanq.exists() || !gold.exists() {
        eprintln!("skipping {name}: artifacts not built (run `make artifacts`)");
        return None;
    }
    let model = QuantizedModel::load(&kanq).expect("load kanq");
    let golden = Container::open(&gold).expect("open golden");
    golden.expect_magic(b"KGLD0001").expect("golden magic");
    Some((model, golden))
}

fn replay(name: &str) {
    let Some((model, golden)) = open_pair(name) else { return };
    let engine = Engine::new(model);
    let (x_q, xs) = golden.u8("x_q").unwrap();
    let (bs, in_dim) = (xs[0], xs[1]);
    assert_eq!(in_dim, engine.model.in_dim());

    // 1. layer-0 B-spline unit outputs must match element-for-element
    //    (driven through the allocation-free batch entry point)
    let l0 = &engine.model.layers[0];
    let unit = BsplineUnit::new(l0.lut.clone(), l0.grid);
    let (want_vals, vshape) = golden.u8("l0.vals").unwrap();
    let (want_k, _) = golden.i32("l0.k").unwrap();
    assert_eq!(vshape, vec![bs, in_dim, l0.degree + 1]);
    let (mut got_vals, mut got_k) = (Vec::new(), Vec::new());
    unit.eval_batch_into(&x_q, &mut got_vals, &mut got_k);
    assert_eq!(got_vals, want_vals, "{name}: l0 unit values diverge");
    let got_k32: Vec<i32> = got_k.iter().map(|&k| k as i32).collect();
    assert_eq!(got_k32, want_k, "{name}: l0 unit indices diverge");

    // 2. intermediate activations after each requantization, replayed
    //    layer by layer through the compiled plan
    let fwd = engine.forward_from_q(&x_q, bs).unwrap();
    let n_layers = engine.model.layers.len();
    let mut cur = x_q.clone();
    for i in 0..n_layers {
        let t = engine.layer_forward(i, &cur, bs);
        if i + 1 < n_layers {
            cur = t.iter().map(|&v| quant::requantize(v)).collect();
            let (want_act, _) = golden.u8(&format!("act{}", i + 1)).unwrap();
            assert_eq!(cur, want_act, "{name}: act{} diverges", i + 1);
        }
    }

    // 3. final accumulators and predictions, exactly — on the wrapper
    //    AND on the planned zero-allocation path
    let (want_t, tshape) = golden.i64("t_final").unwrap();
    assert_eq!(tshape, vec![bs, engine.model.out_dim()]);
    assert_eq!(fwd.t, want_t, "{name}: final accumulators diverge");
    let mut scratch = kan_sas::kan::Scratch::new();
    assert_eq!(
        engine.forward_into(&x_q, bs, &mut scratch).unwrap(),
        &want_t[..],
        "{name}: planned forward_into diverges from golden"
    );
    let (want_pred, _) = golden.i32("pred").unwrap();
    let got_pred: Vec<i32> = fwd.predictions().iter().map(|&p| p as i32).collect();
    assert_eq!(got_pred, want_pred, "{name}: predictions diverge");
}

#[test]
fn quickstart_golden_replays_exactly() {
    replay("quickstart_kan");
}

#[test]
fn mnist_golden_replays_exactly() {
    replay("mnist_kan");
}

#[test]
fn catch22_golden_replays_exactly() {
    replay("catch22_kan");
}

/// Every dispatchable kernel path must replay the golden final
/// accumulators byte for byte — first pinned race-free through
/// `Kernel::forced`, then end to end through the `KANSAS_FORCE_KERNEL`
/// environment override exactly as a user would force it. The env
/// mutation is confined to this one test; concurrent replays in this
/// binary are unaffected because every kernel path is bit-exact.
#[test]
fn golden_replays_exactly_on_every_kernel_path() {
    let Some((model, golden)) = open_pair("mnist_kan") else { return };
    let (x_q, xs) = golden.u8("x_q").unwrap();
    let (want_t, _) = golden.i64("t_final").unwrap();
    for kind in Kernel::available() {
        let engine = Engine::with_kernel(model.clone(), Kernel::forced(kind).unwrap());
        assert_eq!(engine.plan().kernel_kind(), kind);
        let mut scratch = Scratch::new();
        assert_eq!(
            engine.forward_into(&x_q, xs[0], &mut scratch).unwrap(),
            &want_t[..],
            "kernel {kind}: golden final accumulators diverge"
        );
    }
    for kind in Kernel::available() {
        std::env::set_var("KANSAS_FORCE_KERNEL", kind.name());
        let engine = Engine::new(model.clone());
        assert_eq!(engine.plan().kernel_kind(), kind);
        let mut scratch = Scratch::new();
        assert_eq!(
            engine.forward_into(&x_q, xs[0], &mut scratch).unwrap(),
            &want_t[..],
            "KANSAS_FORCE_KERNEL={kind}: golden final accumulators diverge"
        );
    }
    std::env::remove_var("KANSAS_FORCE_KERNEL");
}

/// Packed-precision replay, artifact-free: a deterministic synthetic
/// mixed-precision model must produce identical final accumulators on
/// every kernel path (the packed analogue of the golden replay above —
/// CI also runs this binary with `KANSAS_FORCE_PRECISION=int4`, which
/// pushes every synthetic-model test in the suite through the packed
/// tables, including under `KANSAS_FORCE_KERNEL=scalar`).
#[test]
fn synthetic_mixed_precision_replays_on_every_kernel_path() {
    use kan_sas::kan::Precision;
    let precs = [Precision::Int4, Precision::Int8, Precision::Int4];
    let model = QuantizedModel::synthetic_mixed("gold4", &[9, 14, 7, 5], 5, 3, 2024, &precs);
    let bs = 13usize;
    let x_q: Vec<u8> = (0..bs * 9).map(|i| (i * 71 % 256) as u8).collect();
    let scalar = Engine::with_kernel(model.clone(), Kernel::scalar());
    let mut s = Scratch::new();
    let want = scalar.forward_into(&x_q, bs, &mut s).unwrap().to_vec();
    for kind in Kernel::available() {
        let e = Engine::with_kernel(model.clone(), Kernel::forced(kind).unwrap());
        let mut s = Scratch::new();
        assert_eq!(e.forward_into(&x_q, bs, &mut s).unwrap(), &want[..], "kernel {kind}");
    }
}

/// Artifact-gated: demoting the mnist artifact to int4 produces a
/// DIFFERENT model than the int8 golden vectors — but it must be the
/// SAME model on every kernel path, and its losslessly widened int8
/// twin must reproduce it bit for bit (storage format, not values).
#[test]
fn demoted_artifact_model_is_kernel_invariant() {
    use kan_sas::kan::Precision;
    let Some((model, golden)) = open_pair("mnist_kan") else { return };
    let (x_q, xs) = golden.u8("x_q").unwrap();
    let n = model.layers.len();
    let m4 = model.with_precisions(&vec![Precision::Int4; n]);
    let scalar = Engine::with_kernel(m4.clone(), Kernel::scalar());
    let mut s = Scratch::new();
    let want = scalar.forward_into(&x_q, xs[0], &mut s).unwrap().to_vec();
    let widened = Engine::new(m4.with_precisions(&vec![Precision::Int8; n]));
    let mut sw = Scratch::new();
    assert_eq!(
        widened.forward_into(&x_q, xs[0], &mut sw).unwrap(),
        &want[..],
        "widened int8 twin diverged from the packed int4 model"
    );
    for kind in Kernel::available() {
        let e = Engine::with_kernel(m4.clone(), Kernel::forced(kind).unwrap());
        let mut s = Scratch::new();
        assert_eq!(e.forward_into(&x_q, xs[0], &mut s).unwrap(), &want[..], "kernel {kind}");
    }
}

#[test]
fn golden_labels_give_reasonable_accuracy() {
    // the golden batch carries true labels; the quantized engine should
    // classify most of them correctly (paper: <1% drop from ~96% fp32)
    let Some((model, golden)) = open_pair("mnist_kan") else { return };
    let engine = Engine::new(model);
    let (x_q, xs) = golden.u8("x_q").unwrap();
    let (labels, _) = golden.i32("labels").unwrap();
    let fwd = engine.forward_from_q(&x_q, xs[0]).unwrap();
    let correct = fwd
        .predictions()
        .iter()
        .zip(&labels)
        .filter(|&(&p, &l)| p as i32 == l)
        .count();
    let acc = correct as f64 / labels.len() as f64;
    assert!(acc > 0.9, "golden-batch accuracy {acc}");
}
