//! Fair-dispatch integration: weighted deficit-round-robin keeps a
//! minority tenant's queueing delay bounded under a 10:1 skewed burst,
//! work stealing preserves per-model conservation (including batches
//! stolen during the shutdown flush), and steal counts surface in the
//! stats.

use std::time::Duration;

use kan_sas::arch::ArrayConfig;
use kan_sas::coordinator::{
    BatchPolicy, Dispatch, GatewayBuilder, GatewayConfig, QuotaPolicy, ShedPolicy, TelemetryConfig,
};
use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::loadgen::{self, Focus, MixEntry, Scenario};

fn gateway_config(
    replicas: usize,
    queue_cap: usize,
    policy: BatchPolicy,
    dispatch: Dispatch,
) -> GatewayConfig {
    GatewayConfig {
        replicas,
        queue_cap,
        shed: ShedPolicy::RejectNew,
        policy,
        sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        dispatch,
        quota: QuotaPolicy::None,
        telemetry: TelemetryConfig::default(),
        ..Default::default()
    }
}

/// The satellite acceptance test: a 10:1 skewed-burst mix with the
/// minority tenant service-weighted 8x. The majority tenant's burst
/// overloads the fleet (its own queueing delay blows up with the
/// backlog), but weighted DRR + skip-past-full pulls must keep serving
/// the minority promptly: its p95 *queueing* delay stays strictly below
/// the majority's, and conservation holds per model.
#[test]
fn minority_tenant_queue_delay_bounded_under_skewed_burst() {
    // same (heavy) shape for both tenants: any delay gap is dispatch,
    // not service cost, and per-row compute is large enough that the
    // burst genuinely overloads two replicas on any host
    let major = Engine::new(QuantizedModel::synthetic("major", &[128, 256, 10], 5, 3, 21));
    let minor = Engine::new(QuantizedModel::synthetic("minor", &[128, 256, 10], 5, 3, 22));
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
    let mut b = GatewayBuilder::with_config(gateway_config(2, 512, policy, Dispatch::FairSteal));
    let maj = b.register("major", major);
    let min = b.register_weighted("minor", minor, 8);
    let gw = b.start();
    let entries = [
        MixEntry { handle: gw.handle(maj), weight: 10.0 },
        MixEntry { handle: gw.handle(min), weight: 1.0 },
    ];
    // a hard burst: 10:1 concentrated on the majority, far past what
    // two replicas serve at these dims, so the queue genuinely backs up
    let sc = Scenario::skewed_burst(
        12_000.0,
        4.0,
        Duration::from_millis(600),
        Focus { entry: 0, share: 10.0 / 11.0 },
    );
    let mix = loadgen::run_mix(&entries, &sc, 31);
    let stats = gw.shutdown();

    for (rep, ms) in mix.per_model.iter().zip(&stats.per_model) {
        assert_eq!(rep.submitted, rep.ok + rep.shed + rep.failed, "{}: generator", rep.scenario);
        assert!(ms.conserved(), "{}: {ms:?}", ms.name);
        assert_eq!(ms.submitted, rep.submitted, "{}: generator and gateway agree", ms.name);
    }
    let (maj_stats, min_stats) = (&stats.per_model[0], &stats.per_model[1]);
    assert!(min_stats.completed > 0, "minority tenant was served");
    assert!(
        maj_stats.submitted > 4 * min_stats.submitted,
        "the skew must actually skew: {} vs {}",
        maj_stats.submitted,
        min_stats.submitted
    );
    let maj_q95 = maj_stats.metrics.queue_latency().expect("majority served").p95_us;
    let min_q95 = min_stats.metrics.queue_latency().expect("minority served").p95_us;
    assert!(
        min_q95 < maj_q95,
        "weighted dispatch must bound the minority's queueing: minority p95 {min_q95} us \
         vs majority p95 {maj_q95} us"
    );
    // under this much majority pressure, a starved-minority dispatch
    // would push the fairness index toward 0.5; weighted DRR keeps the
    // weight-normalized shares in the same ballpark
    assert!(
        stats.fairness_index() > 0.5,
        "fairness index {:.3} — minority starved despite weights",
        stats.fairness_index()
    );
}

/// Batches stolen mid-shutdown still conserve per model: every ticket
/// resolves `Ok`, every counter balances, and (retried a few times to
/// dodge scheduling luck) at least one flush batch is actually served
/// by a thief rather than its shard's owner.
#[test]
fn conservation_holds_when_batches_are_stolen_mid_shutdown() {
    let mut saw_steal = false;
    for attempt in 0..6 {
        // heavy models (multi-ms batches), 8 full batches of work, and a
        // shutdown racing the drain: the tail of the backlog lands as
        // multiple due batches in few shards, so workers that empty
        // their own shard steal the stragglers (mid-drain and during the
        // shutdown flush)
        let policy = BatchPolicy { max_batch: 16, max_wait: Duration::from_secs(30) };
        let mut b =
            GatewayBuilder::with_config(gateway_config(4, 512, policy, Dispatch::FairSteal));
        let ids: Vec<_> = (0..4)
            .map(|m| {
                let e = Engine::new(QuantizedModel::synthetic(
                    &format!("steal{m}"),
                    &[128, 256, 10],
                    5,
                    3,
                    60 + m as u64,
                ));
                b.register(&format!("steal{m}"), e)
            })
            .collect();
        let gw = b.start();
        let mut tickets = Vec::new();
        for i in 0..32u8 {
            for &id in &ids {
                let h = gw.handle(id);
                tickets.push(h.submit_q(vec![i; 128]).expect("queue is deep"));
            }
        }
        // shutdown races the pulls: whatever landed in shards drains as
        // a flush, stolen or owner-served; everything still queued is
        // pulled and served before the workers exit
        let stats = gw.shutdown();
        for t in tickets {
            t.wait().expect("every admitted request is served during the flush");
        }
        assert!(stats.conserved(), "attempt {attempt}: {stats:?}");
        assert_eq!(stats.completed(), 128);
        let per_model_rows: u64 =
            stats.per_model.iter().map(|m| m.metrics.batch_rows).sum();
        assert_eq!(per_model_rows, 128, "served rows match completions");
        if stats.stolen_batches() > 0 {
            saw_steal = true;
            break;
        }
    }
    assert!(
        saw_steal,
        "6 attempts, 4 workers, 8 never-due batches across shards: the flush must steal"
    );
}

/// An idle worker steals a *due* batch during normal serving (not just
/// at shutdown): one worker's shard is loaded with two models' due
/// batches; the peer, finding the admission queue empty, must take one.
/// Conservation and correctness hold regardless of who served what.
#[test]
fn steals_spread_load_during_normal_serving() {
    let mut saw_steal = false;
    for _attempt in 0..6 {
        // short window so pulled batches come due immediately; heavy
        // rows so the owning worker is busy long enough to be robbed
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) };
        let mut b =
            GatewayBuilder::with_config(gateway_config(2, 1024, policy, Dispatch::FairSteal));
        let ids: Vec<_> = (0..2)
            .map(|m| {
                let e = Engine::new(QuantizedModel::synthetic(
                    &format!("load{m}"),
                    &[128, 256, 10],
                    5,
                    3,
                    80 + m as u64,
                ));
                b.register(&format!("load{m}"), e)
            })
            .collect();
        let gw = b.start();
        let mut tickets = Vec::new();
        // several waves of both models back-to-back: one worker pulls a
        // multi-model chunk, its peer finds the queue empty and steals
        for wave in 0..6u8 {
            for i in 0..8u8 {
                for &id in &ids {
                    tickets.push(gw.handle(id).submit_q(vec![i.wrapping_add(wave); 128]).unwrap());
                }
            }
            for t in tickets.drain(..) {
                t.wait().expect("healthy gateway serves everything");
            }
        }
        let stats = gw.shutdown();
        assert!(stats.conserved());
        assert_eq!(stats.completed(), 6 * 16);
        if stats.stolen_batches() > 0 {
            saw_steal = true;
            break;
        }
    }
    assert!(saw_steal, "no steal observed across 6 runs of multi-model waves");
}
