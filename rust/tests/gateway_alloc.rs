//! Hard acceptance gate for response-buffer pooling and the telemetry
//! hot path: after warmup, the gateway's per-model [`BufferPool`] must
//! serve acquire→release cycles with ZERO heap allocations (counting
//! global allocator, same technique as `tests/zero_alloc.rs`), the
//! telemetry [`EventRing`]/[`LogHistogram`] primitives must record —
//! and overflow — without touching the heap, and an end-to-end
//! serial-client run with the spine ENABLED must recycle nearly every
//! response buffer instead of allocating per request.
//!
//! Kept to a single `#[test]` on purpose — the counters are
//! process-wide and the default harness runs tests of one binary
//! concurrently, so a second test here could allocate inside the
//! measured window.

use std::time::Duration;

use kan_sas::arch::ArrayConfig;
use kan_sas::coordinator::{
    BatchPolicy, BufferPool, Dispatch, Event, EventKind, EventRing, GatewayBuilder, GatewayConfig,
    LogHistogram, QuotaPolicy, ShedPolicy, TelemetryConfig,
};
use kan_sas::kan::{Engine, Precision, QuantizedModel};
use kan_sas::util::alloc_count::{self, CountingAllocator};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

#[test]
fn response_buffer_pooling_is_allocation_free_after_warmup() {
    // ---- the pool primitive, measured directly ----
    let out_dim = 10usize;
    let pool = BufferPool::new(out_dim, 8);
    // warmup: materialize one buffer (the steady-state working set of a
    // serial client) and park it on the free-list
    let warm = pool.acquire();
    pool.release(warm);
    let row = [7i64; 10];
    let before = alloc_count::events();
    for _ in 0..64 {
        let mut buf = pool.acquire(); // free-list hit: no allocation
        buf.extend_from_slice(&row); // within pre-sized capacity
        assert_eq!(buf.len(), out_dim);
        pool.release(buf); // back to the list: no allocation
    }
    let events = alloc_count::events() - before;
    assert_eq!(
        events, 0,
        "steady-state acquire/extend/release must not touch the heap ({events} allocator events)"
    );
    let (created, recycled, free) = pool.counts();
    assert_eq!(created, 1, "one warmup buffer serves the whole loop");
    assert_eq!(recycled, 64);
    assert_eq!(free, 1);

    // ---- the telemetry primitives, measured directly ----
    // ring push/drain and log-bucket histogram record sit on the serving
    // hot path; once constructed they must never touch the heap (the
    // ring even drops-and-counts on overflow instead of growing)
    let ring = EventRing::new(64);
    let mut hist = LogHistogram::new();
    let ev = |i: u64| Event {
        t_us: i,
        a: i * 3 + 1,
        b: 0,
        trace: 0,
        tenant: 0,
        rows: 1,
        worker: 0,
        kind: EventKind::Admitted,
    };
    let before = alloc_count::events();
    for i in 0..1024u64 {
        ring.push(ev(i)); // past capacity this drops-and-counts
        if i % 100 == 99 {
            ring.drain(|e| hist.record(e.a));
        }
    }
    ring.drain(|e| hist.record(e.a));
    let overflowed = ring.dropped();
    let events = alloc_count::events() - before;
    assert_eq!(
        events, 0,
        "telemetry ring push/drain + histogram record must not touch the heap \
         ({events} allocator events)"
    );
    assert!(overflowed > 0, "a 64-slot ring under 100-push bursts must overflow");
    assert_eq!(hist.count() + overflowed, 1024, "pushed == recorded + dropped");

    // ---- end to end: submit-side buffer cost is amortized ----
    let mut builder = GatewayBuilder::with_config(GatewayConfig {
        replicas: 1,
        queue_cap: 64,
        shed: ShedPolicy::Block,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        dispatch: Dispatch::FairSteal,
        // quotas partition admission, not buffering: the steady-state
        // path must stay allocation-free with them on
        quota: QuotaPolicy::weighted(),
        // the spine stays ON here: emits are two atomic ops into a
        // pre-sized ring, so serving with telemetry adds no allocations
        telemetry: TelemetryConfig::default(),
        ..Default::default()
    });
    // a mixed-precision tenant: the packed int4 layer must not change
    // the serving path's buffer-pooling profile
    let id = builder.register(
        "alloc",
        Engine::new(QuantizedModel::synthetic_mixed(
            "alloc",
            &[8, 12, 10],
            5,
            3,
            31,
            &[Precision::Int4, Precision::Int8],
        )),
    );
    let gateway = builder.start();
    let handle = gateway.handle(id);
    for i in 0..100u64 {
        // drop each response before the next submit: the recycled buffer
        // must cover every subsequent acquire
        let r = handle.infer_q(vec![(i % 256) as u8; 8]).unwrap();
        assert_eq!(r.t.len(), 10);
    }
    let stats = gateway.shutdown();
    let ms = &stats.per_model[0];
    assert_eq!(ms.completed, 100);
    assert!(
        ms.buffers_created <= 2,
        "serial traffic holds at most ~2 buffers live, created {}",
        ms.buffers_created
    );
    assert!(
        ms.buffers_recycled >= 98,
        "steady-state submissions must reuse pooled buffers, recycled only {}",
        ms.buffers_recycled
    );
}
