//! Shared integration-test utilities: bounded polling in place of fixed
//! `thread::sleep` timing guesses (the classic flake source — a loaded
//! CI box blows through any constant), and the `KANSAS_SEED` hook the
//! seeded stress job uses to replay randomized tests.

#![allow(dead_code)] // each test binary compiles this module; none uses all of it

use std::time::{Duration, Instant};

/// Poll `cond` every millisecond until it holds or `timeout` elapses.
/// Returns whether the condition held — callers assert on the result
/// with a message naming what they were waiting for. Replaces fixed
/// sleeps: the wait ends as soon as the state is reached (fast machines
/// don't stall) and only the pathological case pays the full timeout
/// (loaded machines don't flake).
pub fn poll_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        if cond() {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Base seed for randomized tests: the `KANSAS_SEED` environment
/// variable when set (the CI stress matrix pins it), else `default`.
/// Tests print the seed they ran with so a failure names its replay.
pub fn base_seed(default: u64) -> u64 {
    match std::env::var("KANSAS_SEED") {
        Ok(s) => s.trim().parse().expect("KANSAS_SEED must parse as u64"),
        Err(_) => default,
    }
}
