//! Registry-churn integration: a live gateway survives hot-add,
//! re-weight, and remove under load. The invariants under test are the
//! drain-on-remove contract (per-model conservation across the
//! transition, zero lost responses — every ticket resolves) and the
//! epoch-swap machinery (workers adopt new snapshots at batch
//! boundaries; a removed tenant's slot and counters stay visible).

mod common;

use std::time::{Duration, Instant};

use kan_sas::arch::ArrayConfig;
use kan_sas::coordinator::{
    BatchPolicy, ChurnKind, Dispatch, DrainMode, GatewayBuilder, GatewayConfig, QuotaPolicy,
    ServeError, ShedPolicy, TelemetryConfig,
};
use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::loadgen::{self, MixEntry, Scenario};

fn config(
    replicas: usize,
    queue_cap: usize,
    shed: ShedPolicy,
    policy: BatchPolicy,
    quota: QuotaPolicy,
) -> GatewayConfig {
    GatewayConfig {
        replicas,
        queue_cap,
        shed,
        policy,
        sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        dispatch: Dispatch::FairSteal,
        quota,
        telemetry: TelemetryConfig::default(),
        ..Default::default()
    }
}

fn light(name: &str, seed: u64) -> Engine {
    Engine::new(QuantizedModel::synthetic(name, &[4, 6, 3], 5, 3, seed))
}

/// Heavy enough that a batch takes real milliseconds — removals race
/// actual in-flight service, not an already-drained fleet.
fn heavy(name: &str, seed: u64) -> Engine {
    Engine::new(QuantizedModel::synthetic(name, &[128, 256, 10], 5, 3, seed))
}

#[test]
fn add_then_immediately_serve() {
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
    let mut b = GatewayBuilder::with_config(config(
        2,
        64,
        ShedPolicy::RejectNew,
        policy,
        QuotaPolicy::weighted(),
    ));
    let base = b.register("base", light("base", 1));
    let gw = b.start();
    let epoch0 = gw.registry_epoch();
    // serve the original tenant first so workers are mid-steady-state
    assert_eq!(gw.handle(base).infer_q(vec![1, 2, 3, 4]).unwrap().t.len(), 3);
    // hot-add and submit with no grace period: the worker must adopt
    // the new snapshot on its next pull and serve the fresh tenant
    let late = gw.add_model("late", light("late", 2)).unwrap();
    assert_eq!(late.infer_q(vec![4, 3, 2, 1]).unwrap().t.len(), 3);
    assert!(gw.registry_epoch() > epoch0);
    // the new tenant is addressable by name and holds a quota reserve
    assert_eq!(gw.handle_by_name("late").unwrap().model_id(), late.model_id());
    let stats = gw.shutdown();
    assert!(stats.conserved());
    assert_eq!(stats.per_model.len(), 2);
    assert_eq!(stats.per_model[1].completed, 1);
    assert!(stats.per_model[1].reserved > 0, "hot-added tenant gets reserved slots");
}

#[test]
fn set_weight_mid_burst_keeps_serving() {
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
    let mut b = GatewayBuilder::with_config(config(
        2,
        512,
        ShedPolicy::Block,
        policy,
        QuotaPolicy::None,
    ));
    let a = b.register("steady", heavy("steady", 11));
    let c = b.register("boosted", heavy("boosted", 12));
    let gw = b.start();
    let (ha, hc) = (gw.handle(a), gw.handle(c));
    let mut threads = Vec::new();
    for (h, seed) in [(ha, 0u8), (hc, 7u8)] {
        threads.push(std::thread::spawn(move || {
            for i in 0..60u8 {
                h.infer_q(vec![i.wrapping_add(seed); 128]).expect("healthy gateway serves");
            }
        }));
    }
    // re-weight while both tenants are mid-burst; the change must not
    // drop, duplicate, or stall any in-flight request
    assert!(
        common::poll_until(Duration::from_secs(5), || gw.stats().completed() > 0),
        "bursts reach steady state before the re-weight"
    );
    gw.set_weight(c, 8).unwrap();
    for t in threads {
        t.join().unwrap();
    }
    let stats = gw.shutdown();
    assert!(stats.conserved());
    assert_eq!(stats.completed(), 120);
    assert_eq!(stats.per_model[c.index()].weight, 8, "re-weight visible in final stats");
    assert!(stats.epoch >= 2);
}

#[test]
fn remove_serve_drains_a_coalescing_backlog() {
    // a 10s batching window: the backlog is NOT due on its own, so the
    // drain must come from the removal expediting it — not from luck
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(10) };
    let mut b = GatewayBuilder::with_config(config(
        1,
        64,
        ShedPolicy::RejectNew,
        policy,
        QuotaPolicy::None,
    ));
    let keep = b.register("keep", light("keep", 21));
    let gone = b.register("gone", light("gone", 22));
    let gw = b.start();
    let h = gw.handle(gone);
    let start = Instant::now();
    let tickets: Vec<_> = (0..3u8).map(|i| h.submit_q(vec![i; 4]).unwrap()).collect();
    // 3 < max_batch and far under max_wait: still coalescing
    let removed = gw.remove_model(gone, DrainMode::Serve).unwrap();
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "drain must expedite the batch, not wait out the 10s window"
    );
    for t in tickets {
        t.wait().expect("Serve drain completes the backlog");
    }
    assert_eq!(removed.completed, 3);
    assert!(removed.conserved() && !removed.live);
    // the removed handle rejects; the surviving tenant still serves
    assert!(matches!(h.infer_q(vec![9; 4]).unwrap_err(), ServeError::UnknownModel(_)));
    assert_eq!(gw.handle(keep).infer_q(vec![1, 2, 3, 4]).unwrap().t.len(), 3);
    let stats = gw.shutdown();
    assert!(stats.conserved());
    assert!(!stats.per_model[gone.index()].live);
}

#[test]
fn remove_shed_flushes_backlog_under_overload() {
    // slow service (heavy model, 1 replica) + a deep backlog: the Shed
    // removal must answer everything still waiting, quickly
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
    let mut b = GatewayBuilder::with_config(config(
        1,
        128,
        ShedPolicy::RejectNew,
        policy,
        QuotaPolicy::None,
    ));
    let keep = b.register("keep", heavy("keep", 31));
    let gone = b.register("gone", heavy("gone", 32));
    let gw = b.start();
    let h = gw.handle(gone);
    let tickets: Vec<_> = (0..96u8).map(|i| h.submit_q(vec![i; 128]).unwrap()).collect();
    // let the worker pull some of the backlog into its shard so the
    // flush exercises both locations (shared queue + shard batchers)
    assert!(
        common::poll_until(Duration::from_secs(5), || gw.stats().queue_depth < 96),
        "worker pulls part of the backlog into its shard"
    );
    let removed = gw.remove_model(gone, DrainMode::Shed).unwrap();
    let mut ok = 0u64;
    let mut shed = 0u64;
    for t in tickets {
        match t.wait() {
            Ok(_) => ok += 1,
            Err(ServeError::QueueFull) => shed += 1,
            Err(e) => panic!("unexpected outcome {e}"),
        }
    }
    assert_eq!(ok + shed, 96, "every admitted request resolves exactly once");
    assert!(shed > 0, "a 10s window + slow service: the flush must shed something");
    assert_eq!(removed.submitted, 96);
    assert_eq!(removed.completed, ok);
    assert_eq!(removed.shed, shed);
    assert!(removed.conserved(), "{removed:?}");
    // the survivor is untouched
    assert_eq!(gw.handle(keep).infer_q(vec![5; 128]).unwrap().t.len(), 10);
    assert!(gw.shutdown().conserved());
}

#[test]
fn remove_races_drop_oldest_overload() {
    // DropOldest + a tiny queue + competing floods: eviction, service,
    // and a Shed removal all race; conservation must hold regardless
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
    let mut b = GatewayBuilder::with_config(config(
        2,
        16,
        ShedPolicy::DropOldest,
        policy,
        QuotaPolicy::weighted(),
    ));
    let keep = b.register("keep", heavy("keep", 41));
    let gone = b.register("gone", heavy("gone", 42));
    let gw = b.start();
    let mut floods = Vec::new();
    for (id, seed) in [(keep, 0u8), (gone, 9u8)] {
        let h = gw.handle(id);
        floods.push(std::thread::spawn(move || {
            let mut outcomes = (0u64, 0u64, 0u64); // ok, shed, unknown
            let mut tickets = Vec::new();
            for i in 0..120u8 {
                match h.submit_q(vec![i.wrapping_add(seed); 128]) {
                    Ok(t) => tickets.push(t),
                    Err(ServeError::QueueFull) => outcomes.1 += 1,
                    Err(ServeError::UnknownModel(_)) => {
                        outcomes.2 += 1; // removal landed; stop flooding
                        break;
                    }
                    Err(e) => panic!("unexpected submit error {e}"),
                }
            }
            for t in tickets {
                match t.wait() {
                    Ok(_) => outcomes.0 += 1,
                    Err(ServeError::QueueFull) => outcomes.1 += 1,
                    Err(e) => panic!("unexpected ticket outcome {e}"),
                }
            }
            outcomes
        }));
    }
    assert!(
        common::poll_until(Duration::from_secs(5), || {
            let s = gw.stats();
            s.completed() > 0
                && s.per_model[keep.index()].submitted > 0
                && s.per_model[gone.index()].submitted > 0
        }),
        "both floods are mid-flight before the removal lands"
    );
    let removed = gw.remove_model(gone, DrainMode::Shed).unwrap();
    assert!(removed.conserved(), "{removed:?}");
    let mut total_ok = 0;
    for f in floods {
        let (ok, _shed, _unknown) = f.join().unwrap();
        total_ok += ok;
    }
    let stats = gw.shutdown();
    assert!(stats.conserved(), "{stats:?}");
    assert_eq!(stats.completed(), total_ok, "gateway and clients agree on completions");
    assert!(!stats.per_model[gone.index()].live);
    assert!(stats.per_model[keep.index()].live);
}

/// The acceptance-criteria cycle: a live gateway runs `add_model`,
/// serve, `set_weight`, `remove_model` under open-loop load with quotas
/// on, and per-model conservation holds end to end with zero lost
/// responses.
#[test]
fn full_churn_cycle_under_load() {
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
    let mut b = GatewayBuilder::with_config(config(
        2,
        256,
        ShedPolicy::RejectNew,
        policy,
        QuotaPolicy::weighted(),
    ));
    let a = b.register("app0", light("app0", 51));
    let c = b.register_weighted("app1", light("app1", 52), 2);
    let gw = b.start();
    let entries = vec![
        MixEntry { handle: gw.handle(a), weight: 1.0 },
        MixEntry { handle: gw.handle(c), weight: 1.0 },
    ];
    let sc = Scenario::steady(1200.0, Duration::from_millis(500));
    let events = loadgen::default_churn_events(sc.total_duration());
    let tel = gw.telemetry();
    let mix = loadgen::run_churn(&gw, entries, &sc, &events, 61);
    let stats = gw.shutdown();
    assert_eq!(mix.per_model.len(), 3);
    for (rep, ms) in mix.per_model.iter().zip(&stats.per_model) {
        assert_eq!(
            rep.submitted,
            rep.ok + rep.shed + rep.failed,
            "{}: generator-side conservation",
            rep.scenario
        );
        assert_eq!(ms.submitted, rep.submitted, "{}: gateway agrees", ms.name);
        assert!(ms.conserved(), "{}: {ms:?}", ms.name);
        assert_eq!(rep.failed, 0, "{}: zero lost responses across churn", rep.scenario);
    }
    assert!(stats.conserved());
    let hot = &mix.per_model[2];
    assert!(hot.ok > 0, "the hot-added tenant was actually served: {hot:?}");
    assert!(!stats.per_model[2].live, "the script removes its tenant again");
    // start(1) + add(1) + set_weight(1) + remove(2)
    assert!(stats.epoch >= 5, "the full cycle moves the epoch, got {}", stats.epoch);

    // the flight recorder saw the whole cycle, in transition order:
    // two registrations, then the scripted add → reweight → remove
    let dump = tel.flight_dump();
    let kinds: Vec<ChurnKind> = dump.churn.iter().map(|c| c.kind).collect();
    assert_eq!(
        kinds,
        vec![
            ChurnKind::Registered,
            ChurnKind::Registered,
            ChurnKind::Added,
            ChurnKind::Reweighted,
            ChurnKind::RemoveBegin,
            ChurnKind::Removed,
        ],
        "churn records in order, got {:?}",
        dump.churn
    );
    assert_eq!(dump.churn[2].name, "hotswap");
    assert_eq!(dump.churn[3].weight, 4, "the reweight records the new weight");
    assert_eq!(dump.churn[5].name, "hotswap");
    let mut last = 0u64;
    for c in &dump.churn {
        assert!(c.t_us >= last, "flight recorder timestamps are monotonic: {:?}", dump.churn);
        last = c.t_us;
    }
    // the hot-added tenant's slot retains lifecycle events
    assert_eq!(dump.tenants[2].0, "hotswap");
    assert!(!dump.tenants[2].1.is_empty(), "served tenant leaves flight events");
}
