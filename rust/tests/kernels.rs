//! Differential tests for the SIMD MAC kernel layer (`kan::kernel`):
//! every kernel path compiled into this binary and supported by the
//! running CPU must reproduce the scalar reference **bit for bit** over
//! random `(G, P, dims, bs)` — remainder lanes included — and the fused
//! requantize path must equal the unfused combine + requantize chain on
//! every path. Complements the unit tests in `kan/kernel.rs` (raw
//! mac4/axpy vs independent oracles) by exercising whole plans, and
//! `tests/golden_replay.rs` (each path vs the python golden vectors).

use kan_sas::kan::{Engine, ExecutionPlan, Kernel, KernelKind, Precision, QuantizedModel, Scratch};
use kan_sas::quant;
use kan_sas::util::rng::{check, Rng};

/// Full-plan differential: random multi-layer models, awkward widths.
#[test]
fn every_kernel_path_matches_scalar_over_random_shapes() {
    check(30, 2024, |rng: &mut Rng| {
        let g = 1 + rng.below(8);
        let p = 1 + rng.below(3);
        let n_layers = 1 + rng.below(3);
        // deliberately awkward widths: 1..=34 crosses the 8- and 16-lane
        // vector bodies plus every possible remainder
        let dims: Vec<usize> = (0..=n_layers).map(|_| 1 + rng.below(34)).collect();
        let bs = 1 + rng.below(40); // routinely NOT a multiple of the batch block
        let model = QuantizedModel::synthetic("kdiff", &dims, g, p, rng.below(1 << 30) as u64);
        let x_q: Vec<u8> = (0..bs * dims[0]).map(|_| rng.below(256) as u8).collect();
        let scalar = Engine::with_kernel(model.clone(), Kernel::scalar());
        let mut s = Scratch::new();
        let want = scalar.forward_into(&x_q, bs, &mut s).unwrap().to_vec();
        for kind in Kernel::available() {
            if kind == KernelKind::Scalar {
                continue;
            }
            let e = Engine::with_kernel(model.clone(), Kernel::forced(kind).unwrap());
            let mut s = Scratch::new();
            assert_eq!(
                e.forward_into(&x_q, bs, &mut s).unwrap(),
                &want[..],
                "kernel {kind}: g={g} p={p} dims={dims:?} bs={bs}"
            );
        }
    });
}

/// Deterministic worst-case remainders: out_dims 17/23/33 leave 1-, 7-
/// and 1-lane tails on the 16-wide mac4 bodies; bs=37 is coprime to
/// every batch-block candidate.
#[test]
fn remainder_lane_shapes_bit_exact() {
    let model = QuantizedModel::synthetic("rem", &[23, 33, 17, 10], 5, 3, 9);
    let bs = 37usize;
    let x_q: Vec<u8> = (0..bs * 23).map(|i| (i * 101 % 256) as u8).collect();
    let scalar = Engine::with_kernel(model.clone(), Kernel::scalar());
    let mut s = Scratch::new();
    let want = scalar.forward_into(&x_q, bs, &mut s).unwrap().to_vec();
    for kind in Kernel::available() {
        let e = Engine::with_kernel(model.clone(), Kernel::forced(kind).unwrap());
        assert_eq!(e.plan().kernel_kind(), kind);
        let mut s = Scratch::new();
        assert_eq!(e.forward_into(&x_q, bs, &mut s).unwrap(), &want[..], "kernel {kind}");
    }
}

/// Packed-int4 full-plan differential: random mixed-precision models
/// (always at least one int4 layer) must match BOTH the scalar packed
/// reference and the dense int8 plan of the losslessly widened twin —
/// the widening changes only the storage format, so any divergence is a
/// nibble decode bug, not quantization. Multi-layer models drive the
/// fused inter-layer requantize through the packed accumulators too.
#[test]
fn every_kernel_path_matches_scalar_on_packed_models() {
    check(20, 4044, |rng: &mut Rng| {
        let g = 1 + rng.below(8);
        let p = 1 + rng.below(3);
        let n_layers = 1 + rng.below(3);
        let dims: Vec<usize> = (0..=n_layers).map(|_| 1 + rng.below(34)).collect();
        let bs = 1 + rng.below(40);
        let mut precs: Vec<Precision> = (0..n_layers)
            .map(|_| if rng.below(2) == 0 { Precision::Int4 } else { Precision::Int8 })
            .collect();
        precs[rng.below(n_layers)] = Precision::Int4;
        let seed = rng.below(1 << 30) as u64;
        let model = QuantizedModel::synthetic_mixed("kp4", &dims, g, p, seed, &precs);
        let x_q: Vec<u8> = (0..bs * dims[0]).map(|_| rng.below(256) as u8).collect();
        let scalar = Engine::with_kernel(model.clone(), Kernel::scalar());
        let mut s = Scratch::new();
        let want = scalar.forward_into(&x_q, bs, &mut s).unwrap().to_vec();
        let widened = Engine::with_kernel(
            model.with_precisions(&vec![Precision::Int8; n_layers]),
            Kernel::scalar(),
        );
        let mut sw = Scratch::new();
        assert_eq!(
            widened.forward_into(&x_q, bs, &mut sw).unwrap(),
            &want[..],
            "packed scalar != dense scalar on identical values: g={g} p={p} dims={dims:?}"
        );
        for kind in Kernel::available() {
            if kind == KernelKind::Scalar {
                continue;
            }
            let e = Engine::with_kernel(model.clone(), Kernel::forced(kind).unwrap());
            let mut s = Scratch::new();
            assert_eq!(
                e.forward_into(&x_q, bs, &mut s).unwrap(),
                &want[..],
                "kernel {kind}: g={g} p={p} dims={dims:?} bs={bs} precs={precs:?}"
            );
        }
    });
}

/// Deterministic packed worst-case remainders: odd out_dims (33, 17)
/// pad a tail nibble in every row; 10 crosses the 16-lane body with a
/// 10-lane tail; bs=37 stays coprime to the batch-block candidates.
#[test]
fn packed_remainder_lane_shapes_bit_exact() {
    let precs = [Precision::Int4, Precision::Int4, Precision::Int8, Precision::Int4];
    let model = QuantizedModel::synthetic_mixed("rem4", &[23, 33, 17, 10], 5, 3, 9, &precs);
    let bs = 37usize;
    let x_q: Vec<u8> = (0..bs * 23).map(|i| (i * 101 % 256) as u8).collect();
    let scalar = Engine::with_kernel(model.clone(), Kernel::scalar());
    let mut s = Scratch::new();
    let want = scalar.forward_into(&x_q, bs, &mut s).unwrap().to_vec();
    for kind in Kernel::available() {
        let e = Engine::with_kernel(model.clone(), Kernel::forced(kind).unwrap());
        assert_eq!(e.plan().kernel_kind(), kind);
        let mut s = Scratch::new();
        assert_eq!(e.forward_into(&x_q, bs, &mut s).unwrap(), &want[..], "kernel {kind}");
    }
}

/// The fused inter-layer path (combine + requantize in one pass, no i64
/// buffer) must equal the unfused chain on every kernel path.
#[test]
fn fused_requantize_matches_unfused_on_every_kernel() {
    check(20, 777, |rng: &mut Rng| {
        let g = 1 + rng.below(6);
        let p = 1 + rng.below(3);
        let k = 1 + rng.below(20);
        let n = 1 + rng.below(33);
        let bs = 1 + rng.below(20);
        let model = QuantizedModel::synthetic("fused", &[k, n], g, p, rng.below(1 << 30) as u64);
        let x_q: Vec<u8> = (0..bs * k).map(|_| rng.below(256) as u8).collect();
        for kind in Kernel::available() {
            let plan = ExecutionPlan::compile_with(&model, Kernel::forced(kind).unwrap());
            let lp = &plan.layers[0];
            let mut acc = vec![0i32; bs * n];
            let mut acc_base = vec![0i32; bs * n];
            let mut t = vec![0i64; bs * n];
            lp.forward_into(&x_q, bs, &mut acc, &mut acc_base, &mut t);
            let unfused: Vec<u8> = t.iter().map(|&v| quant::requantize(v)).collect();
            let mut fused = vec![0u8; bs * n];
            lp.forward_requant_into(&x_q, bs, &mut acc, &mut acc_base, &mut fused);
            assert_eq!(fused, unfused, "kernel {kind}: g={g} p={p} k={k} n={n} bs={bs}");
        }
    });
}

/// `KANSAS_FORCE_KERNEL` end to end: pins every available path, warns
/// and falls back on unknown or unavailable names, and clears cleanly.
/// Env mutation lives in this single test; every other test in this
/// binary pins kernels through `Kernel::forced`, so there is no race.
#[test]
fn force_kernel_env_pins_and_falls_back() {
    let best = Kernel::available()[0];
    for kind in Kernel::available() {
        std::env::set_var("KANSAS_FORCE_KERNEL", kind.name());
        assert_eq!(Kernel::dispatch().kind(), kind, "forcing {kind}");
    }
    // unknown kernel name: warn + fall back to the best available
    std::env::set_var("KANSAS_FORCE_KERNEL", "quantum9");
    assert_eq!(Kernel::dispatch().kind(), best);
    // compiled-out-or-unsupported (neon on x86, avx2 on aarch64):
    // warn + fall back rather than abort
    let foreign = if cfg!(target_arch = "x86_64") {
        KernelKind::Neon
    } else {
        KernelKind::Avx2
    };
    if !Kernel::available().contains(&foreign) {
        std::env::set_var("KANSAS_FORCE_KERNEL", foreign.name());
        assert_eq!(Kernel::dispatch().kind(), best);
    }
    std::env::remove_var("KANSAS_FORCE_KERNEL");
    assert_eq!(Kernel::dispatch().kind(), best);
}

/// An engine compiled under a forced path serves the same bytes through
/// the full stack (staged path included) as the dispatched engine.
#[test]
fn forced_engines_agree_on_staged_path() {
    let model = QuantizedModel::synthetic("staged_k", &[12, 24, 5], 5, 3, 31);
    let x_q: Vec<u8> = (0..6 * 12).map(|i| (i * 41 % 256) as u8).collect();
    let mut want: Option<Vec<i64>> = None;
    for kind in Kernel::available() {
        let e = Engine::with_kernel(model.clone(), Kernel::forced(kind).unwrap());
        let mut s = Scratch::new();
        s.stage_input(x_q.len()).extend_from_slice(&x_q);
        let got = e.forward_staged(6, &mut s).unwrap().to_vec();
        match &want {
            None => want = Some(got),
            Some(w) => assert_eq!(&got, w, "kernel {kind} diverges on the staged path"),
        }
    }
}
