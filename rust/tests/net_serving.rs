//! Network front door integration: round-trip correctness vs the
//! direct engine, malformed-frame handling (typed error frames, the
//! connection survives what it can and closes when framing is lost),
//! telemetry/models over the wire, and the drop-mid-flight conservation
//! guarantee — a client that disconnects with requests in flight must
//! not break per-model `submitted == completed + shed + failed`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use kan_sas::arch::ArrayConfig;
use kan_sas::coordinator::net::{
    code, encode_control, encode_request, FrameHeader, FrameType, HEADER_LEN,
};
use kan_sas::coordinator::{
    BatchPolicy, Dispatch, Gateway, GatewayBuilder, GatewayConfig, NetClient, NetConfig, NetServer,
    QuotaPolicy, ServeError, ShedPolicy, TelemetryConfig,
};
use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::util::json::Value;
use kan_sas::util::rng::Rng;

/// One-tenant gateway over a synthetic model built from `seed` —
/// rebuilding with the same seed gives a bit-identical engine for
/// direct-path comparison.
fn gateway_with(name: &str, dims: &[usize], seed: u64, replicas: usize) -> Gateway {
    let mut b = GatewayBuilder::with_config(GatewayConfig {
        replicas,
        queue_cap: 1024,
        shed: ShedPolicy::RejectNew,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        dispatch: Dispatch::FairSteal,
        quota: QuotaPolicy::None,
        telemetry: TelemetryConfig::default(),
        ..Default::default()
    });
    b.register(name, Engine::new(QuantizedModel::synthetic(name, dims, 5, 3, seed)));
    b.start()
}

fn read_frame(stream: &mut TcpStream) -> Option<(FrameHeader, Vec<u8>)> {
    let mut hdr = [0u8; HEADER_LEN];
    stream.read_exact(&mut hdr).ok()?;
    let h = FrameHeader::decode(&hdr).expect("server frames are well-formed");
    let mut payload = vec![0u8; h.len as usize];
    stream.read_exact(&mut payload).ok()?;
    Some((h, payload))
}

#[test]
fn round_trip_matches_direct_engine() {
    let dims = [6usize, 10, 4];
    let gateway = gateway_with("rt", &dims, 71, 1);
    let direct = Engine::new(QuantizedModel::synthetic("rt", &dims, 5, 3, 71));
    let server = NetServer::start("127.0.0.1:0", &gateway, NetConfig::default()).unwrap();
    let client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    let handle = client.handle("rt").unwrap();
    assert_eq!(handle.in_dim(), 6);
    assert_eq!(handle.out_dim(), 4);

    let mut rng = Rng::new(5);
    for _ in 0..32 {
        let row: Vec<u8> = (0..handle.in_dim()).map(|_| rng.below(256) as u8).collect();
        let resp = handle.infer_q(row.clone()).expect("remote inference");
        let fwd = direct.forward_from_q(&row, 1).expect("direct inference");
        assert_eq!(resp.t, fwd.t, "wire logits must match the direct engine");
        assert!(resp.e2e_us >= resp.queue_us, "e2e includes the server's queueing share");
    }

    // wrong row width is rejected client-side with the typed error
    match handle.submit_q(vec![1, 2, 3]) {
        Err(ServeError::InvalidInput(_)) => {}
        other => panic!("expected InvalidInput for a short row, got {other:?}"),
    }

    drop(client);
    server.shutdown();
    let stats = gateway.shutdown();
    assert_eq!(stats.per_model[0].completed, 32);
    assert!(stats.per_model[0].conserved());
}

#[test]
fn stats_and_models_served_over_the_wire() {
    let gateway = gateway_with("tele", &[4, 6, 3], 9, 1);
    let server = NetServer::start("127.0.0.1:0", &gateway, NetConfig::default()).unwrap();
    let client = NetClient::connect(&server.local_addr().to_string()).unwrap();

    let models = client.models().unwrap();
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].name, "tele");
    assert_eq!((models[0].in_dim, models[0].out_dim), (4, 3));

    // serve some traffic so the snapshot has content, then poll it
    let handle = client.handle_for(&models[0]);
    for i in 0..8u8 {
        handle.infer_q(vec![i; 4]).unwrap();
    }
    let json = client.stats_json().expect("stats over the wire");
    let v = Value::parse(&json).expect("snapshot renders as valid JSON");
    let tenants = v.get("tenants").and_then(Value::as_arr).expect("snapshot has tenants");
    assert_eq!(tenants.len(), 1);
    assert_eq!(tenants[0].get("name").and_then(Value::as_str), Some("tele"));

    drop(client);
    server.shutdown();
    let stats = gateway.shutdown();
    assert_eq!(stats.per_model[0].completed, 8);
}

#[test]
fn malformed_frames_answer_typed_errors_and_survive() {
    let gateway = gateway_with("mf", &[4, 6, 3], 13, 1);
    let server = NetServer::start("127.0.0.1:0", &gateway, NetConfig::default()).unwrap();
    let mut raw = TcpStream::connect(server.local_addr()).unwrap();
    let mut buf = Vec::new();

    // 1) bad magic, zero length: typed MALFORMED error, connection lives
    let mut hdr = [0u8; HEADER_LEN];
    FrameHeader { ty: FrameType::InferRequest, code: 0, corr: 7, model: 0, deadline_us: 0, len: 0 }
        .encode(&mut hdr);
    hdr[0] = b'X';
    raw.write_all(&hdr).unwrap();
    let (h, payload) = read_frame(&mut raw).expect("error frame for bad magic");
    assert_eq!(h.ty, FrameType::Error);
    assert_eq!(h.code, code::MALFORMED);
    assert_eq!(h.corr, 7);
    assert!(std::str::from_utf8(&payload).unwrap().contains("magic"));

    // 2) unknown model id: typed UNKNOWN_MODEL, payload skipped,
    //    connection lives
    encode_request(&mut buf, 8, 99, &[1, 2, 3, 4], 0, 0);
    raw.write_all(&buf).unwrap();
    let (h, _) = read_frame(&mut raw).expect("error frame for unknown model");
    assert_eq!((h.ty, h.code, h.corr), (FrameType::Error, code::UNKNOWN_MODEL, 8));

    // 3) wrong row width for a real model: typed INVALID_INPUT
    encode_request(&mut buf, 9, 0, &[1, 2], 0, 0);
    raw.write_all(&buf).unwrap();
    let (h, _) = read_frame(&mut raw).expect("error frame for bad width");
    assert_eq!((h.ty, h.code, h.corr), (FrameType::Error, code::INVALID_INPUT, 9));

    // 4) the same connection still serves valid traffic after all that
    encode_request(&mut buf, 10, 0, &[5, 6, 7, 8], 0, 0);
    raw.write_all(&buf).unwrap();
    let (h, payload) = read_frame(&mut raw).expect("InferOk after recovered errors");
    assert_eq!((h.ty, h.corr), (FrameType::InferOk, 10));
    assert_eq!(payload.len(), 16 + 8 * 3, "timing split + out_dim logits");

    // 5) a response-type frame from a client is malformed but survivable
    encode_control(&mut buf, FrameType::StatsResponse, 11);
    raw.write_all(&buf).unwrap();
    let (h, _) = read_frame(&mut raw).expect("error frame for reversed direction");
    assert_eq!((h.ty, h.code, h.corr), (FrameType::Error, code::MALFORMED, 11));

    // 6) an oversized length is unrecoverable: error frame, then close
    let mut big = TcpStream::connect(server.local_addr()).unwrap();
    let mut hdr = [0u8; HEADER_LEN];
    FrameHeader {
        ty: FrameType::InferRequest,
        code: 0,
        corr: 12,
        model: 0,
        deadline_us: 0,
        len: (NetConfig::default().max_frame + 1) as u32,
    }
    .encode(&mut hdr);
    big.write_all(&hdr).unwrap();
    let (h, _) = read_frame(&mut big).expect("error frame before close");
    assert_eq!((h.ty, h.code), (FrameType::Error, code::MALFORMED));
    let mut probe = [0u8; 1];
    assert_eq!(big.read(&mut probe).unwrap_or(0), 0, "server closes after losing sync");

    drop(raw);
    let net = server.shutdown();
    assert!(net.malformed >= 3, "malformed counter tracks protocol errors, got {}", net.malformed);
    let stats = gateway.shutdown();
    assert_eq!(stats.per_model[0].completed, 1, "only the one valid frame reached the gateway");
    assert!(stats.per_model[0].conserved());
}

#[test]
fn client_drop_mid_flight_conserves_per_model() {
    // one slow-ish replica so a burst is genuinely in flight at drop time
    let gateway = gateway_with("drop", &[32, 48, 8], 23, 1);
    let server = NetServer::start("127.0.0.1:0", &gateway, NetConfig::default()).unwrap();
    let client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    let handle = client.handle("drop").unwrap();

    let burst = 64usize;
    let mut tickets = Vec::with_capacity(burst);
    for i in 0..burst {
        let row = vec![(i % 256) as u8; handle.in_dim()];
        tickets.push(handle.submit_q(row).expect("burst submit"));
    }
    // disconnect with the burst in flight: the server's writer drains
    // every admitted ticket (the bytes go nowhere), the gateway still
    // serves and counts each one
    drop(tickets);
    drop(client);

    // wait for the connection to fully drain (EOF consumes every frame
    // the client wrote before the FIN) so `stop` can't race the reader
    // out of admitting the tail of the burst
    let t0 = Instant::now();
    while server.connections() > 0 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(10));
    }
    server.shutdown();
    let stats = gateway.shutdown();
    let ms = &stats.per_model[0];
    assert_eq!(ms.submitted, burst as u64, "every frame admitted before the disconnect");
    assert!(
        ms.conserved(),
        "drop-mid-flight must not leak outcomes: submitted {} completed {} shed {} failed {}",
        ms.submitted,
        ms.completed,
        ms.shed,
        ms.failed
    );
    assert_eq!(ms.completed + ms.shed + ms.failed, burst as u64);
}

#[test]
fn abandoned_client_tickets_resolve_closed() {
    let gateway = gateway_with("closed", &[4, 6, 3], 37, 1);
    let server = NetServer::start("127.0.0.1:0", &gateway, NetConfig::default()).unwrap();
    let client = NetClient::connect(&server.local_addr().to_string()).unwrap();
    let handle = client.handle("closed").unwrap();
    // a ticket held across server shutdown resolves (Ok if the drain
    // served it, Closed if the connection died first) instead of hanging
    let t = handle.submit_q(vec![1, 2, 3, 4]).unwrap();
    server.shutdown();
    match t.wait() {
        Ok(resp) => assert_eq!(resp.t.len(), 3),
        Err(ServeError::Closed) => {}
        Err(e) => panic!("expected Ok or Closed after server shutdown, got {e:?}"),
    }
    gateway.shutdown();
}
