//! Coordinator integration: conservation (every request answered exactly
//! once — including under load-shedding and shutdown races), batching
//! behaviour under concurrency, replica weight-sharing, metrics sanity,
//! and the multi-tenant gateway (two models over one fleet: correctness
//! through typed handles, per-model conservation under overload races,
//! DropOldest eviction semantics). Uses the quickstart artifact when
//! present, otherwise a hand-built tiny model.

use std::path::PathBuf;
use std::time::Duration;

use kan_sas::arch::ArrayConfig;
use kan_sas::bspline::Lut;
use kan_sas::coordinator::{
    BatchPolicy, Dispatch, GatewayBuilder, GatewayConfig, Pool, PoolConfig, PoolError, Priority,
    QuotaPolicy, Request, Server, ServerConfig, ServeError, ShedPolicy, TelemetryConfig,
};
use kan_sas::kan::{Engine, LayerParams, Precision, QuantizedModel};
use kan_sas::tensor::Tensor;
use kan_sas::util::rng::Rng;

fn tiny_engine() -> Engine {
    let (g, p, k, n) = (5usize, 3usize, 4usize, 3usize);
    let m = g + p;
    let mut rng = Rng::new(99);
    let coeff: Vec<i8> = (0..k * m * n).map(|_| rng.range_i64(-50, 50) as i8).collect();
    let base: Vec<i8> = (0..k * n).map(|_| rng.range_i64(-50, 50) as i8).collect();
    Engine::new(QuantizedModel {
        name: "tiny".into(),
        dims: vec![k, n],
        layers: vec![LayerParams {
            in_dim: k,
            out_dim: n,
            grid: g,
            degree: p,
            lut: Lut::build(p),
            coeff: Tensor::from_vec(coeff, &[k, m, n]),
            base: Tensor::from_vec(base, &[k, n]),
            m1: 1000,
            m2: 1000,
            s1: 1.0,
            s2: 1.0,
            precision: Precision::Int8,
        }],
    })
}

fn load_engine() -> Engine {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/quickstart_kan.kanq");
    if path.exists() {
        Engine::new(QuantizedModel::load(&path).unwrap())
    } else {
        tiny_engine()
    }
}

#[test]
fn every_request_answered_exactly_once() {
    let engine = load_engine();
    let in_dim = engine.model.in_dim();
    let server = Server::start(
        engine,
        ServerConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        },
    );
    let n_clients = 4;
    let per_client = 50;
    let mut threads = Vec::new();
    for c in 0..n_clients {
        let h = server.handle();
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64);
            let mut answered = 0;
            for _ in 0..per_client {
                let x: Vec<f32> = (0..in_dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
                let resp = h.infer(&x).expect("inference");
                assert!(!resp.t.is_empty());
                answered += 1;
            }
            answered
        }));
    }
    let total: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
    assert_eq!(total, n_clients * per_client);
    let metrics = server.shutdown();
    let lat = metrics.latency().unwrap();
    assert_eq!(lat.count, n_clients * per_client, "latency sample per request");
    assert_eq!(metrics.batch_rows as usize, n_clients * per_client, "rows conserved");
    assert!(metrics.batches as usize <= n_clients * per_client);
    assert!(metrics.sim_cycles > 0, "simulated cycles attached");
}

#[test]
fn batching_actually_batches() {
    // with a generous deadline and many concurrent clients the mean batch
    // size must exceed 1 (requests coalesce)
    let server = Server::start(
        load_engine(),
        ServerConfig {
            policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(20) },
            sim_array: ArrayConfig::conventional(8, 8),
        },
    );
    let in_dim = server.handle().infer(&vec![0.0; 0]).err().map(|_| ()).is_some();
    let _ = in_dim;
    let engine_dim = 4; // quickstart/tiny both have in_dim 4
    let mut threads = Vec::new();
    for c in 0..8 {
        let h = server.handle();
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            for _ in 0..20 {
                let x: Vec<f32> = (0..engine_dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
                h.infer(&x).unwrap();
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let metrics = server.shutdown();
    assert!(
        metrics.mean_batch_size() > 1.2,
        "mean batch size {} — dynamic batching not coalescing",
        metrics.mean_batch_size()
    );
}

#[test]
fn deterministic_responses() {
    // same input always yields the same accumulators (pure integer path)
    let server = Server::start(load_engine(), ServerConfig::default());
    let h = server.handle();
    let x = vec![0.25f32, -0.5, 0.75, 0.1];
    let a = h.infer(&x).unwrap();
    let b = h.infer(&x).unwrap();
    assert_eq!(a.t, b.t);
    let _ = a.prediction();
    server.shutdown();
}

#[test]
fn wrong_dim_rejected() {
    let server = Server::start(load_engine(), ServerConfig::default());
    assert!(server.handle().infer(&[0.0; 3]).is_err());
    server.shutdown();
}

// ---------------- pool (multi-replica + admission control) ----------------

fn pool_config(replicas: usize, queue_cap: usize, shed: ShedPolicy) -> PoolConfig {
    PoolConfig {
        replicas,
        queue_cap,
        shed,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        dispatch: Dispatch::FairSteal,
        quota: QuotaPolicy::None,
        telemetry: TelemetryConfig::default(),
        ..Default::default()
    }
}

#[test]
fn pool_conserves_under_load_shedding() {
    // a deliberately tiny queue + RejectNew: every submission must get
    // exactly one terminal outcome (Ok or QueueFull), and the client-side
    // tallies must reconcile exactly with the pool's own counters
    let pool = Pool::start(load_engine(), pool_config(2, 4, ShedPolicy::RejectNew));
    let in_dim = pool.handle().in_dim();
    let n_clients = 6;
    let per_client = 120;
    let mut threads = Vec::new();
    for c in 0..n_clients {
        let h = pool.handle();
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(500 + c as u64);
            let (mut ok, mut shed) = (0u64, 0u64);
            // burst tickets to put real pressure on the admission queue
            let mut tickets = Vec::new();
            for i in 0..per_client {
                let x_q: Vec<u8> = (0..in_dim).map(|_| rng.below(256) as u8).collect();
                match h.submit_q(x_q) {
                    Ok(t) => tickets.push(t),
                    Err(PoolError::QueueFull) => shed += 1,
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
                if i % 16 == 15 {
                    // drain the burst so some requests also complete
                    for t in tickets.drain(..) {
                        match t.wait() {
                            Ok(r) => {
                                ok += 1;
                                assert!(!r.t.is_empty());
                            }
                            Err(PoolError::QueueFull) => shed += 1,
                            Err(e) => panic!("unexpected terminal: {e}"),
                        }
                    }
                }
            }
            for t in tickets {
                match t.wait() {
                    Ok(_) => ok += 1,
                    Err(PoolError::QueueFull) => shed += 1,
                    Err(e) => panic!("unexpected terminal: {e}"),
                }
            }
            (ok, shed)
        }));
    }
    let (mut ok, mut shed) = (0u64, 0u64);
    for t in threads {
        let (o, s) = t.join().unwrap();
        ok += o;
        shed += s;
    }
    let total = (n_clients * per_client) as u64;
    assert_eq!(ok + shed, total, "every submission answered exactly once");
    let stats = pool.shutdown();
    assert_eq!(stats.submitted, total);
    assert_eq!(stats.completed, ok);
    assert_eq!(stats.shed, shed);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.submitted, stats.completed + stats.shed + stats.failed, "conservation");
    assert_eq!(stats.merged.latency().map(|l| l.count).unwrap_or(0) as u64, ok);
    assert_eq!(stats.merged.batch_rows, ok, "served rows == completed requests");
    assert!(stats.peak_depth <= 4, "bounded queue respected");
}

#[test]
fn pool_conserves_across_shutdown_race() {
    // clients keep submitting while the pool shuts down mid-flight: each
    // submission still resolves exactly once (Ok | QueueFull | Closed),
    // and everything admitted before close is served, never dropped
    let pool = Pool::start(load_engine(), pool_config(3, 64, ShedPolicy::RejectNew));
    let in_dim = pool.handle().in_dim();
    let mut threads = Vec::new();
    for c in 0..4 {
        let h = pool.handle();
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(900 + c as u64);
            let (mut ok, mut shed, mut closed) = (0u64, 0u64, 0u64);
            let mut submitted = 0u64;
            loop {
                let x_q: Vec<u8> = (0..in_dim).map(|_| rng.below(256) as u8).collect();
                submitted += 1;
                match h.infer_q(x_q) {
                    Ok(_) => ok += 1,
                    Err(PoolError::QueueFull) => shed += 1,
                    Err(PoolError::Closed) => {
                        closed += 1;
                        break;
                    }
                    Err(e) => panic!("unexpected terminal: {e}"),
                }
            }
            (submitted, ok, shed, closed)
        }));
    }
    std::thread::sleep(Duration::from_millis(30));
    let stats = pool.shutdown();
    let (mut submitted, mut ok, mut shed, mut closed) = (0u64, 0u64, 0u64, 0u64);
    for t in threads {
        let (su, o, s, cl) = t.join().unwrap();
        submitted += su;
        ok += o;
        shed += s;
        closed += cl;
    }
    assert_eq!(submitted, ok + shed + closed, "every submission resolved exactly once");
    assert!(ok > 0, "pool served requests before shutdown");
    // pool-side counters exclude Closed (never admitted, never shed)
    assert_eq!(stats.submitted, ok + shed);
    assert_eq!(stats.completed, ok);
    assert_eq!(stats.shed, shed);
    assert_eq!(stats.merged.batch_rows, ok, "admitted-before-close requests all served");
}

#[test]
fn pool_replicas_share_weights_and_balance_load() {
    let engine = load_engine();
    let replica = engine.clone();
    assert!(engine.shares_weights_with(&replica), "replicas alias one weight allocation");
    assert_eq!(
        engine.model.layers[0].coeff.data().as_ptr(),
        replica.model.layers[0].coeff.data().as_ptr(),
        "coefficient tensors alias one allocation (pool memory ~flat in replicas)"
    );
    let pool = Pool::start(engine, pool_config(4, 256, ShedPolicy::Block));
    let h = pool.handle();
    let in_dim = h.in_dim();
    let mut threads = Vec::new();
    for c in 0..8 {
        let h = h.clone();
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64);
            for _ in 0..40 {
                let x_q: Vec<u8> = (0..in_dim).map(|_| rng.below(256) as u8).collect();
                h.infer_q(x_q).expect("Block policy never sheds");
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let stats = pool.shutdown();
    assert_eq!(stats.completed, 8 * 40);
    assert_eq!(stats.per_replica.len(), 4);
    let rows: u64 = stats.per_replica.iter().map(|m| m.batch_rows).sum();
    assert_eq!(rows, 8 * 40, "per-replica rows sum to the total");
    let busy = stats.per_replica.iter().filter(|m| m.batch_rows > 0).count();
    assert!(busy >= 2, "work spread across replicas (got {busy} busy of 4)");
    assert!(stats.merged.sim_cycles > 0, "simulated cycles attached per replica");
}

#[test]
fn pool_deterministic_same_input_same_logits() {
    let pool = Pool::start(load_engine(), pool_config(3, 64, ShedPolicy::Block));
    let h = pool.handle();
    let x = vec![0.25f32, -0.5, 0.75, 0.1];
    let a = h.infer(&x).unwrap();
    // replicas are bit-identical: whichever worker serves it, same t
    for _ in 0..10 {
        assert_eq!(h.infer(&x).unwrap().t, a.t);
    }
    pool.shutdown();
}

// ---------------- gateway (multi-tenant, one fleet) ----------------

fn gateway_config(replicas: usize, queue_cap: usize, shed: ShedPolicy) -> GatewayConfig {
    GatewayConfig {
        replicas,
        queue_cap,
        shed,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        dispatch: Dispatch::FairSteal,
        quota: QuotaPolicy::None,
        telemetry: TelemetryConfig::default(),
        ..Default::default()
    }
}

fn second_engine() -> Engine {
    Engine::new(QuantizedModel::synthetic("wide", &[6, 9, 5], 5, 3, 77))
}

/// The acceptance-criteria test: two models through one gateway, both
/// answering *correct* predictions (bit-exact against direct engine
/// forwards), with per-model rows/latency in the stats.
#[test]
fn gateway_two_models_answer_correct_predictions() {
    let engine_a = tiny_engine();
    let engine_b = second_engine();
    // reference replicas alias the registered engines' weights
    let (ref_a, ref_b) = (engine_a.clone(), engine_b.clone());
    let mut builder = GatewayBuilder::with_config(gateway_config(3, 256, ShedPolicy::Block));
    let id_a = builder.register("tiny", engine_a);
    let id_b = builder.register("wide", engine_b);
    let gateway = builder.start();
    let (ha, hb) = (gateway.handle(id_a), gateway.handle(id_b));
    assert_eq!((ha.in_dim(), ha.out_dim()), (4, 3));
    assert_eq!((hb.in_dim(), hb.out_dim()), (6, 5));
    let mut rng = Rng::new(321);
    for i in 0..60 {
        let (h, reference, k) =
            if i % 2 == 0 { (&ha, &ref_a, 4) } else { (&hb, &ref_b, 6) };
        let x_q: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
        let want = reference.forward_from_q(&x_q, 1).unwrap();
        let got = h.infer_q(x_q).unwrap();
        assert_eq!(got.t, want.t, "gateway answer diverged from direct engine forward");
        assert_eq!(got.prediction(), want.predictions()[0]);
        assert_eq!(got.latency_us(), got.queue_us + got.service_us);
    }
    let stats = gateway.shutdown();
    assert_eq!(stats.per_model.len(), 2);
    for (ms, want_rows) in stats.per_model.iter().zip([30u64, 30]) {
        assert_eq!(ms.completed, want_rows);
        assert_eq!(ms.metrics.batch_rows, want_rows, "per-model rows tracked");
        let lat = ms.metrics.latency().expect("per-model latency recorded");
        assert_eq!(lat.count as u64, want_rows);
        assert!(ms.conserved(), "{}: {ms:?}", ms.name);
    }
    assert_eq!(stats.merged.batch_rows, 60);
    assert!(stats.conserved());
}

/// Mixed-precision tenant set (acceptance criteria for the sub-8-bit
/// engine): one int8 model and one packed-int4 model through the same
/// gateway fleet, both answering bit-exact against direct engine
/// forwards, per-model conservation intact, and the int4 tenant's
/// compiled tables measurably smaller than its widened-int8 twin's.
#[test]
fn gateway_serves_mixed_precision_tenants() {
    let engine_a = tiny_engine(); // int8
    let model_b =
        QuantizedModel::synthetic_mixed("nibble", &[6, 9, 5], 5, 3, 77, &[Precision::Int4; 2]);
    assert_eq!(model_b.precisions(), vec![Precision::Int4; 2]);
    let engine_b = Engine::new(model_b.clone());
    let dense_twin = Engine::new(model_b.with_precisions(&[Precision::Int8; 2]));
    assert!(
        engine_b.plan().derived_bytes() < dense_twin.plan().derived_bytes(),
        "int4 tenant must compile into fewer table bytes"
    );
    let (ref_a, ref_b) = (engine_a.clone(), engine_b.clone());
    let mut builder = GatewayBuilder::with_config(gateway_config(3, 256, ShedPolicy::Block));
    let id_a = builder.register("tiny", engine_a);
    let id_b = builder.register("nibble", engine_b);
    let gateway = builder.start();
    let (ha, hb) = (gateway.handle(id_a), gateway.handle(id_b));
    let mut rng = Rng::new(888);
    for i in 0..60 {
        let (h, reference, k) = if i % 2 == 0 { (&ha, &ref_a, 4) } else { (&hb, &ref_b, 6) };
        let x_q: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
        let want = reference.forward_from_q(&x_q, 1).unwrap();
        let got = h.infer_q(x_q).unwrap();
        assert_eq!(got.t, want.t, "mixed-precision gateway answer diverged");
        // the int4 tenant must also agree with its lossless int8 widening
        if i % 2 == 1 {
            let x_q2: Vec<u8> = (0..6).map(|_| rng.below(256) as u8).collect();
            assert_eq!(
                hb.infer_q(x_q2.clone()).unwrap().t,
                dense_twin.forward_from_q(&x_q2, 1).unwrap().t,
                "packed tenant diverged from its widened twin"
            );
        }
    }
    let stats = gateway.shutdown();
    assert_eq!(stats.per_model.len(), 2);
    for ms in &stats.per_model {
        assert!(ms.conserved(), "{}: {ms:?}", ms.name);
    }
    assert!(stats.conserved());
}

/// Per-model conservation under a concurrent two-model overload race:
/// a deliberately tiny shared queue, bursty ticket traffic on both
/// tenants, client-side tallies reconciled exactly against the
/// gateway's per-model counters.
#[test]
fn gateway_conserves_per_model_under_overload_race() {
    for shed in [ShedPolicy::RejectNew, ShedPolicy::DropOldest] {
        let mut builder = GatewayBuilder::with_config(gateway_config(2, 4, shed));
        let id_a = builder.register("tiny", tiny_engine());
        let id_b = builder.register("wide", second_engine());
        let gateway = builder.start();
        let n_clients = 3; // per model
        let per_client = 80;
        let mut threads = Vec::new();
        for model in 0..2usize {
            for c in 0..n_clients {
                let h = gateway.handle(if model == 0 { id_a } else { id_b });
                threads.push(std::thread::spawn(move || {
                    let mut rng = Rng::new((model * 100 + c) as u64);
                    let in_dim = h.in_dim();
                    let (mut ok, mut shed) = (0u64, 0u64);
                    let mut tickets = Vec::new();
                    for i in 0..per_client {
                        let x_q: Vec<u8> = (0..in_dim).map(|_| rng.below(256) as u8).collect();
                        match h.submit_q(x_q) {
                            Ok(t) => tickets.push(t),
                            Err(ServeError::QueueFull) => shed += 1,
                            Err(e) => panic!("unexpected submit error: {e}"),
                        }
                        if i % 16 == 15 {
                            for t in tickets.drain(..) {
                                match t.wait() {
                                    Ok(r) => {
                                        ok += 1;
                                        assert_eq!(r.t.len(), h.out_dim());
                                    }
                                    Err(ServeError::QueueFull) => shed += 1,
                                    Err(e) => panic!("unexpected terminal: {e}"),
                                }
                            }
                        }
                    }
                    for t in tickets {
                        // every ticket resolves — DropOldest evictions
                        // answer QueueFull, they never hang
                        match t.wait() {
                            Ok(_) => ok += 1,
                            Err(ServeError::QueueFull) => shed += 1,
                            Err(e) => panic!("unexpected terminal: {e}"),
                        }
                    }
                    (model, ok, shed)
                }));
            }
        }
        let mut ok_by = [0u64; 2];
        let mut shed_by = [0u64; 2];
        for t in threads {
            let (model, o, s) = t.join().unwrap();
            ok_by[model] += o;
            shed_by[model] += s;
        }
        let stats = gateway.shutdown();
        let total = (n_clients * per_client) as u64;
        for m in 0..2 {
            assert_eq!(ok_by[m] + shed_by[m], total, "every submission answered once ({shed:?})");
            let ms = &stats.per_model[m];
            assert_eq!(ms.submitted, total);
            assert_eq!(ms.completed, ok_by[m], "{}: completed", ms.name);
            assert_eq!(ms.shed, shed_by[m], "{}: shed", ms.name);
            assert_eq!(ms.failed, 0);
            assert!(ms.conserved(), "{}: {ms:?}", ms.name);
            assert_eq!(ms.metrics.batch_rows, ok_by[m], "served rows == completions");
        }
        assert!(stats.peak_depth <= 4, "bounded queue respected");
    }
}

/// DropOldest + priority classes, end to end: a High-priority burst
/// evicts queued Low traffic (answered `QueueFull`, never hung) while
/// High requests survive to completion.
#[test]
fn gateway_drop_oldest_prefers_low_priority_victims() {
    // one slow-ish worker and a small queue so evictions actually happen
    let mut builder = GatewayBuilder::with_config(GatewayConfig {
        replicas: 1,
        queue_cap: 8,
        shed: ShedPolicy::DropOldest,
        policy: BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
        sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        dispatch: Dispatch::FairSteal,
        quota: QuotaPolicy::None,
        telemetry: TelemetryConfig::default(),
        ..Default::default()
    });
    // heavy enough that service can't keep pace with the submit burst,
    // so the queue genuinely overflows and evicts
    let heavy = Engine::new(QuantizedModel::synthetic("heavy", &[64, 128, 10], 5, 3, 50));
    let id = builder.register("heavy", heavy);
    let gateway = builder.start();
    let h = gateway.handle(id);
    // only 4 High requests total — fewer than the queue capacity, so a
    // full queue ALWAYS holds a Low victim and no High can ever be
    // evicted (eviction would need an all-High queue)
    let mut low = Vec::new();
    let mut high = Vec::new();
    let mut low_shed = 0u64;
    for i in 0..200u64 {
        let x_q = vec![(i % 256) as u8; 64];
        let req = Request::from_q(x_q);
        if i % 50 == 0 {
            match h.submit(req.with_priority(Priority::High)) {
                Ok(t) => high.push(t),
                Err(e) => panic!("High submit must always admit here: {e}"),
            }
        } else {
            match h.submit(req.with_priority(Priority::Low)) {
                Ok(t) => low.push(t),
                Err(ServeError::QueueFull) => low_shed += 1,
                Err(e) => panic!("unexpected: {e}"),
            }
        }
    }
    let mut low_ok = 0u64;
    for t in low {
        // evicted tickets resolve QueueFull — they never hang
        match t.wait() {
            Ok(_) => low_ok += 1,
            Err(ServeError::QueueFull) => low_shed += 1,
            Err(e) => panic!("unexpected terminal: {e}"),
        }
    }
    for t in high {
        t.wait().expect("High priority must never be evicted ahead of queued Low traffic");
    }
    let stats = gateway.shutdown();
    let ms = &stats.per_model[0];
    assert!(ms.conserved(), "{ms:?}");
    assert_eq!(ms.shed, low_shed, "every shed was a Low request");
    assert_eq!(ms.completed, low_ok + 4);
    assert!(low_shed > 0, "the burst must actually overflow the tiny queue");
}
