//! Coordinator integration: conservation (every request answered exactly
//! once), batching behaviour under concurrency, metrics sanity. Uses the
//! quickstart artifact when present, otherwise a hand-built tiny model.

use std::path::PathBuf;
use std::time::Duration;

use kan_sas::arch::ArrayConfig;
use kan_sas::bspline::Lut;
use kan_sas::coordinator::{BatchPolicy, Server, ServerConfig};
use kan_sas::kan::{Engine, LayerParams, QuantizedModel};
use kan_sas::tensor::Tensor;
use kan_sas::util::rng::Rng;

fn tiny_engine() -> Engine {
    let (g, p, k, n) = (5usize, 3usize, 4usize, 3usize);
    let m = g + p;
    let mut rng = Rng::new(99);
    let coeff: Vec<i8> = (0..k * m * n).map(|_| rng.range_i64(-50, 50) as i8).collect();
    let base: Vec<i8> = (0..k * n).map(|_| rng.range_i64(-50, 50) as i8).collect();
    Engine::new(QuantizedModel {
        name: "tiny".into(),
        dims: vec![k, n],
        layers: vec![LayerParams {
            in_dim: k,
            out_dim: n,
            grid: g,
            degree: p,
            lut: Lut::build(p),
            coeff: Tensor::from_vec(coeff, &[k, m, n]),
            base: Tensor::from_vec(base, &[k, n]),
            m1: 1000,
            m2: 1000,
            s1: 1.0,
            s2: 1.0,
        }],
    })
}

fn load_engine() -> Engine {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/quickstart_kan.kanq");
    if path.exists() {
        Engine::new(QuantizedModel::load(&path).unwrap())
    } else {
        tiny_engine()
    }
}

#[test]
fn every_request_answered_exactly_once() {
    let engine = load_engine();
    let in_dim = engine.model.in_dim();
    let server = Server::start(
        engine,
        ServerConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
        },
    );
    let n_clients = 4;
    let per_client = 50;
    let mut threads = Vec::new();
    for c in 0..n_clients {
        let h = server.handle();
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64);
            let mut answered = 0;
            for _ in 0..per_client {
                let x: Vec<f32> = (0..in_dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
                let resp = h.infer(&x).expect("inference");
                assert!(!resp.t.is_empty());
                answered += 1;
            }
            answered
        }));
    }
    let total: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
    assert_eq!(total, n_clients * per_client);
    let metrics = server.shutdown();
    let lat = metrics.latency().unwrap();
    assert_eq!(lat.count, n_clients * per_client, "latency sample per request");
    assert_eq!(metrics.batch_rows as usize, n_clients * per_client, "rows conserved");
    assert!(metrics.batches as usize <= n_clients * per_client);
    assert!(metrics.sim_cycles > 0, "simulated cycles attached");
}

#[test]
fn batching_actually_batches() {
    // with a generous deadline and many concurrent clients the mean batch
    // size must exceed 1 (requests coalesce)
    let server = Server::start(
        load_engine(),
        ServerConfig {
            policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(20) },
            sim_array: ArrayConfig::conventional(8, 8),
        },
    );
    let in_dim = server.handle().infer(&vec![0.0; 0]).err().map(|_| ()).is_some();
    let _ = in_dim;
    let engine_dim = 4; // quickstart/tiny both have in_dim 4
    let mut threads = Vec::new();
    for c in 0..8 {
        let h = server.handle();
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            for _ in 0..20 {
                let x: Vec<f32> = (0..engine_dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
                h.infer(&x).unwrap();
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let metrics = server.shutdown();
    assert!(
        metrics.mean_batch_size() > 1.2,
        "mean batch size {} — dynamic batching not coalescing",
        metrics.mean_batch_size()
    );
}

#[test]
fn deterministic_responses() {
    // same input always yields the same accumulators (pure integer path)
    let server = Server::start(load_engine(), ServerConfig::default());
    let h = server.handle();
    let x = vec![0.25f32, -0.5, 0.75, 0.1];
    let a = h.infer(&x).unwrap();
    let b = h.infer(&x).unwrap();
    assert_eq!(a.t, b.t);
    let _ = a.prediction();
    server.shutdown();
}

#[test]
fn wrong_dim_rejected() {
    let server = Server::start(load_engine(), ServerConfig::default());
    assert!(server.handle().infer(&[0.0; 3]).is_err());
    server.shutdown();
}
