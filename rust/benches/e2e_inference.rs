//! Bench: end-to-end integer inference (the serving hot path) across
//! batch sizes, plus the simulated accelerator cycles per batch.
//!
//! Measures three execution paths so the perf trajectory of the planned
//! refactor stays machine-checkable:
//!
//! * `forward_into` — the compiled-plan, scratch-arena path (zero
//!   steady-state allocations; see `tests/zero_alloc.rs`), running the
//!   runtime-dispatched SIMD kernel;
//! * `scalar` — the identical planned path pinned to the scalar
//!   reference kernel (the PR-6 baseline), so every row of SIMD uplift
//!   is attributable and comparable across machines;
//! * `forward_from_q` — the allocating compatibility wrapper, whose
//!   per-call allocation profile matches the pre-plan engine.
//!
//! Results (throughput, p50/p95/p99 latency, allocs-per-forward for all
//! paths, the dispatched kernel name, and the autotuned per-layer batch
//! blocks) are written to `BENCH_engine.json` in the working directory.
//! Falls back to a synthetic MNIST-shaped model when artifacts are not
//! built, so the bench always runs offline.

use std::path::PathBuf;

use kan_sas::arch::ArrayConfig;
use kan_sas::bench::{bench, write_artifact, BenchStats};
use kan_sas::kan::{Engine, Kernel, Precision, QuantizedModel, Scratch};
use kan_sas::util::alloc_count::{self, CountingAllocator};
use kan_sas::util::json::Value;
use kan_sas::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn path_json(s: &BenchStats, bs: usize, allocs_per_forward: f64) -> Value {
    Value::obj([
        ("rows_per_s", Value::num(s.per_second(bs as u64))),
        ("p50_us", Value::num(s.median.as_secs_f64() * 1e6)),
        ("p95_us", Value::num(s.p95.as_secs_f64() * 1e6)),
        ("p99_us", Value::num(s.p99.as_secs_f64() * 1e6)),
        ("allocs_per_forward", Value::num(allocs_per_forward)),
    ])
}

/// Allocator events per call of `f`, averaged over `reps` runs.
fn allocs_per_call<F: FnMut()>(reps: u64, mut f: F) -> f64 {
    let before = alloc_count::events();
    for _ in 0..reps {
        f();
    }
    (alloc_count::events() - before) as f64 / reps as f64
}

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let path = dir.join("mnist_kan.kanq");
    let (model, synthetic) = if path.exists() {
        (QuantizedModel::load(&path).unwrap(), false)
    } else {
        eprintln!("artifacts not built — benching a synthetic MNIST-shaped model");
        (QuantizedModel::synthetic("mnist_kan_synth", &[784, 64, 10], 5, 3, 3), true)
    };
    // the dispatched engine (best kernel for this CPU, or
    // KANSAS_FORCE_KERNEL) and the pinned-scalar baseline over the SAME
    // model, so the uplift column is same-weights same-machine
    let scalar_engine = Engine::with_kernel(model.clone(), Kernel::scalar());
    let engine = Engine::new(model);
    let kernel = engine.plan().kernel_kind();
    let blocks = engine.plan().batch_blocks();
    println!(
        "kernel: {kernel} (available: {}); batch blocks: {blocks:?}",
        Kernel::available().iter().map(|k| k.name()).collect::<Vec<_>>().join(",")
    );
    let in_dim = engine.model.in_dim();
    let mut rng = Rng::new(3);
    let mut batches = Vec::new();

    for bs in [1usize, 8, 32, 128] {
        let x_q: Vec<u8> = (0..bs * in_dim).map(|_| rng.below(256) as u8).collect();
        let mut scratch = Scratch::for_plan(engine.plan(), bs);
        let mut scratch_s = Scratch::for_plan(scalar_engine.plan(), bs);

        let planned = bench(&format!("{} planned forward_into, bs={bs}", engine.model.name), || {
            let t = engine.forward_into(&x_q, bs, &mut scratch).unwrap();
            std::hint::black_box(t[t.len() - 1]);
        });
        let scalar = bench(&format!("{} scalar baseline, bs={bs}", engine.model.name), || {
            let t = scalar_engine.forward_into(&x_q, bs, &mut scratch_s).unwrap();
            std::hint::black_box(t[t.len() - 1]);
        });
        let wrapper = bench(&format!("{} wrapper forward_from_q, bs={bs}", engine.model.name), || {
            std::hint::black_box(engine.forward_from_q(&x_q, bs).unwrap().t.len());
        });

        // allocator events per forward on each path (planned must be 0
        // after warmup — hard-asserted by tests/zero_alloc.rs; reported
        // here so BENCH_engine.json tracks the before/after trajectory)
        let allocs_planned = allocs_per_call(64, || {
            std::hint::black_box(engine.forward_into(&x_q, bs, &mut scratch).unwrap().len());
        });
        let allocs_scalar = allocs_per_call(64, || {
            let t = scalar_engine.forward_into(&x_q, bs, &mut scratch_s).unwrap();
            std::hint::black_box(t.len());
        });
        let allocs_wrapper = allocs_per_call(64, || {
            std::hint::black_box(engine.forward_from_q(&x_q, bs).unwrap().t.len());
        });

        let sim = engine.simulate_batch(&ArrayConfig::kan_sas(16, 16, 4, 8), bs);
        println!(
            "    -> {:.0} rows/s planned [{kernel}] ({:.0} scalar, {:.0} wrapper); \
             allocs/forward {:.1}; simulated KAN-SAs 16x16: {} cycles ({:.1} us @500MHz)",
            planned.per_second(bs as u64),
            scalar.per_second(bs as u64),
            wrapper.per_second(bs as u64),
            allocs_planned,
            sim.cycles,
            sim.cycles as f64 * 2e-3
        );

        batches.push(Value::obj([
            ("bs", Value::num(bs as f64)),
            ("planned", path_json(&planned, bs, allocs_planned)),
            ("scalar", path_json(&scalar, bs, allocs_scalar)),
            ("wrapper", path_json(&wrapper, bs, allocs_wrapper)),
            ("sim_cycles", Value::num(sim.cycles as f64)),
        ]));
    }

    // precision sweep: the SAME weights stored per layer as widened int8
    // vs packed int4 (demoted, multipliers rescaled exactly) vs an
    // alternating mixed plan, all at one serving batch size. rows/s +
    // table bytes quantify the memory/throughput trade of the nibble
    // packing; argmax agreement vs the int8 row bounds the accuracy cost
    // of the demotion.
    let sweep_bs = 32usize;
    let x_q: Vec<u8> = (0..sweep_bs * in_dim).map(|_| rng.below(256) as u8).collect();
    let n_layers = engine.model.layers.len();
    let variants: Vec<(&str, Vec<Precision>)> = vec![
        ("int8", vec![Precision::Int8; n_layers]),
        ("int4", vec![Precision::Int4; n_layers]),
        (
            "mixed",
            (0..n_layers)
                .map(|i| if i % 2 == 0 { Precision::Int4 } else { Precision::Int8 })
                .collect(),
        ),
    ];
    let mut sweep = Vec::new();
    let mut int8_preds: Vec<usize> = Vec::new();
    for (vname, precs) in &variants {
        let e = Engine::new(engine.model.as_ref().with_precisions(precs));
        let mut s = Scratch::for_plan(e.plan(), sweep_bs);
        let stats = bench(&format!("{} precision sweep [{vname}], bs={sweep_bs}", e.model.name), || {
            let t = e.forward_into(&x_q, sweep_bs, &mut s).unwrap();
            std::hint::black_box(t[t.len() - 1]);
        });
        let preds = e.forward_from_q(&x_q, sweep_bs).unwrap().predictions();
        if *vname == "int8" {
            int8_preds = preds.clone();
        }
        let agree = preds.iter().zip(&int8_preds).filter(|(a, b)| a == b).count();
        let table_bytes = e.plan().derived_bytes();
        println!(
            "    -> [{vname}] {:.0} rows/s, {table_bytes} table bytes, \
             argmax agreement {agree}/{sweep_bs} vs int8",
            stats.per_second(sweep_bs as u64)
        );
        sweep.push(Value::obj([
            ("precision", Value::str(*vname)),
            ("rows_per_s", Value::num(stats.per_second(sweep_bs as u64))),
            ("p50_us", Value::num(stats.median.as_secs_f64() * 1e6)),
            ("p95_us", Value::num(stats.p95.as_secs_f64() * 1e6)),
            ("table_bytes", Value::num(table_bytes as f64)),
            ("param_bytes", Value::num(e.param_bytes() as f64)),
            ("agree_vs_int8", Value::num(agree as f64 / sweep_bs as f64)),
        ]));
    }

    let doc = Value::obj([
        ("bench", Value::str("e2e_inference")),
        ("model", Value::str(engine.model.name.clone())),
        ("synthetic", Value::Bool(synthetic)),
        ("param_bytes", Value::num(engine.param_bytes() as f64)),
        ("kernel", Value::str(kernel.name())),
        (
            "batch_blocks",
            Value::arr(blocks.iter().map(|&bb| Value::num(bb as f64)).collect::<Vec<_>>()),
        ),
        ("batches", Value::arr(batches)),
        ("precision_sweep", Value::arr(sweep)),
    ]);
    let out = "BENCH_engine.json";
    write_artifact(out, doc).expect("write bench artifact");
    println!("wrote {out} (sections merge-appended)");
}
