//! Bench: end-to-end integer inference (the serving hot path) across
//! batch sizes, plus the simulated accelerator cycles per batch.

use std::path::PathBuf;

use kan_sas::arch::ArrayConfig;
use kan_sas::bench::bench_val;
use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::util::rng::Rng;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let path = dir.join("mnist_kan.kanq");
    if !path.exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    }
    let engine = Engine::new(QuantizedModel::load(&path).unwrap());
    let in_dim = engine.model.in_dim();
    let mut rng = Rng::new(3);

    for bs in [1usize, 8, 32, 128] {
        let x_q: Vec<u8> = (0..bs * in_dim).map(|_| rng.below(256) as u8).collect();
        let stats = bench_val(&format!("mnist_kan int8 forward, bs={bs}"), || {
            engine.forward_from_q(&x_q, bs).unwrap()
        });
        let sim = engine.simulate_batch(&ArrayConfig::kan_sas(16, 16, 4, 8), bs);
        println!(
            "    -> {:.0} rows/s on CPU; simulated KAN-SAs 16x16: {} cycles ({:.1} us @500MHz)",
            stats.per_second(bs as u64),
            sim.cycles,
            sim.cycles as f64 * 2e-3
        );
    }
}
