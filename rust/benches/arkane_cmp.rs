//! Bench: Sec. V-B — tabulated B-spline unit vs the ArKANe recursive
//! dataflow model, plus the real unit's software throughput.

use kan_sas::bench::bench_val;
use kan_sas::bspline::{BsplineUnit, Lut};
use kan_sas::bspline::reference;
use kan_sas::experiments;
use kan_sas::util::rng::Rng;

fn main() {
    print!("{}", experiments::arkane_comparison().render());

    println!("\n=== software B-spline evaluation (functional models) ===");
    let mut rng = Rng::new(2);
    let xs_q: Vec<u8> = (0..65536).map(|_| rng.below(256) as u8).collect();
    let xs_f: Vec<f64> = xs_q.iter().map(|&q| (q as f64 - 128.0) / 128.0).collect();
    let unit = BsplineUnit::new(Lut::build(3), 5);

    let s_lut = bench_val("tabulated unit: 64k inputs (all 8 bases each)", || {
        let mut acc = 0u32;
        for &x in &xs_q {
            let (vals, k) = unit.eval_into(x);
            acc = acc.wrapping_add(vals.iter().map(|&v| v as u32).sum::<u32>() + k as u32);
        }
        acc
    });
    let s_rec = bench_val("Cox-de Boor recursion: 64k inputs (f64 oracle)", || {
        let knots = reference::make_grid(5, 3, -1.0, 1.0);
        let mut acc = 0.0f64;
        for &x in &xs_f {
            acc += reference::cox_de_boor(x, &knots, 3).iter().sum::<f64>();
        }
        acc
    });
    println!(
        "\nsoftware speedup tabulation vs recursion: {:.1}x (hardware equal-area model: >=72x)",
        s_rec.median.as_secs_f64() / s_lut.median.as_secs_f64()
    );
}
