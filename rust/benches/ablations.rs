//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. weight-load policy (amortized double-buffering vs counted loads) —
//!    how much of KAN-SAs' cycle advantage survives if coefficient loads
//!    serialize with compute;
//! 2. GKAN G/P variants — the N:M pattern's effect on the utilization gap;
//! 3. CF-KAN dataset sizes — imperfect tiling vs layer width;
//! 4. LUT depth — ROM bits vs worst-case B-spline value error.

use kan_sas::arch::{ArrayConfig, WeightLoad};
use kan_sas::bspline::reference;
use kan_sas::report::Table;
use kan_sas::sim::analytic;
use kan_sas::util::round_clamp;
use kan_sas::workloads;

fn main() {
    weight_load_ablation();
    gkan_ablation();
    cfkan_ablation();
    lut_depth_ablation();
}

fn weight_load_ablation() {
    let apps = workloads::fig7_workloads();
    let mut t = Table::new(&["policy", "conv 32x32 cycles", "KAN-SAs 16x16 cycles", "ratio"])
        .with_title("Ablation 1 — weight-load accounting (all Fig. 7 apps, G=5 P=3)");
    for (policy, label) in [(WeightLoad::Amortized, "amortized (paper)"), (WeightLoad::Counted, "counted")] {
        let mut conv = ArrayConfig::conventional(32, 32);
        let mut kan = ArrayConfig::kan_sas(16, 16, 4, 8);
        conv.weight_load = policy;
        kan.weight_load = policy;
        let c: u64 = apps.iter().map(|(_, w)| analytic::simulate_app(&conv, w).cycles).sum();
        let k: u64 = apps.iter().map(|(_, w)| analytic::simulate_app(&kan, w).cycles).sum();
        t.row(vec![
            label.into(),
            c.to_string(),
            k.to_string(),
            format!("{:.2}x", c as f64 / k as f64),
        ]);
    }
    print!("{}", t.render());
    println!();
}

fn gkan_ablation() {
    let mut t = Table::new(&["G", "P", "N:M", "conv util %", "KAN-SAs util %", "cycle ratio"])
        .with_title("Ablation 2 — GKAN G/P variants (paper Table II: G in {2,3}, P in {1,2,3})");
    for (g, p, wls) in workloads::gkan_variants() {
        let conv = ArrayConfig::conventional(32, 32);
        let kan = ArrayConfig::kan_sas(16, 16, p + 1, g + p);
        let cs = analytic::simulate_app(&conv, &wls);
        let ks = analytic::simulate_app(&kan, &wls);
        t.row(vec![
            g.to_string(),
            p.to_string(),
            format!("{}:{}", p + 1, g + p),
            format!("{:.1}", cs.utilization() * 100.0),
            format!("{:.1}", ks.utilization() * 100.0),
            format!("{:.2}x", cs.cycles as f64 / ks.cycles as f64),
        ]);
    }
    print!("{}", t.render());
    println!();
}

fn cfkan_ablation() {
    let mut t = Table::new(&["X", "conv util %", "KAN-SAs util %", "cycle ratio"])
        .with_title("Ablation 3 — CF-KAN dataset sizes (X in Table II)");
    for (x, wls) in workloads::cfkan_variants() {
        let conv = ArrayConfig::conventional(32, 32);
        let kan = ArrayConfig::kan_sas(16, 16, 4, 5);
        let cs = analytic::simulate_app(&conv, &wls);
        let ks = analytic::simulate_app(&kan, &wls);
        t.row(vec![
            x.to_string(),
            format!("{:.1}", cs.utilization() * 100.0),
            format!("{:.1}", ks.utilization() * 100.0),
            format!("{:.2}x", cs.cycles as f64 / ks.cycles as f64),
        ]);
    }
    print!("{}", t.render());
    println!();
}

fn lut_depth_ablation() {
    // worst-case |LUT dequant - exact B_{0,3}| across a dense input sweep,
    // for different ROM depths: the paper's 256 rows vs alternatives
    let p = 3;
    let peak = reference::cardinal_peak(p);
    let mut t = Table::new(&["LUT rows", "ROM bits (full)", "max abs err", "err / peak %"])
        .with_title("Ablation 4 — tabulation depth vs B-spline value error (P=3)");
    for rows in [32usize, 64, 128, 256, 512, 1024] {
        let scale = peak / 255.0;
        let mut max_err = 0.0f64;
        for i in 0..8192 {
            let u = 4.0 * i as f64 / 8192.0; // support [0, P+1)
            let exact = reference::cardinal_bspline(u, p);
            // quantize u to the row grid the same way the unit does
            let frac = u.fract();
            let base = u.trunc();
            let addr = ((frac * rows as f64) as usize).min(rows - 1);
            let stored =
                round_clamp(reference::cardinal_bspline(addr as f64 / rows as f64 + base, p) / scale, 0, 255)
                    as f64
                    * scale;
            max_err = max_err.max((stored - exact).abs());
        }
        t.row(vec![
            rows.to_string(),
            (rows * (p + 1) * 8).to_string(),
            format!("{max_err:.5}"),
            format!("{:.2}", 100.0 * max_err / peak),
        ]);
    }
    print!("{}", t.render());
    println!("(256 rows — the paper's 8-bit address — keeps worst-case error ~1 quantization LSB)");
}
