//! Serving scale sweep: replica count x offered load x model mix x
//! dispatch policy.
//!
//! Eight measurements, all on synthetic models (offline, no artifacts):
//!
//! 1. **Closed-loop saturation** per replica count — peak rows/sec with
//!    16 hammering clients. The acceptance bar is >= 2x rows/sec at 4
//!    replicas vs 1 on the steady load; weights stay one Arc-shared
//!    allocation, so pool memory is ~flat in replica count (printed, and
//!    asserted by `clones_alias_one_weight_allocation` in kan::engine).
//! 2. **Open-loop scenario mixes** at fixed replicas — offered vs
//!    achieved rate, shed rate, and tail latency for steady / diurnal /
//!    flash-crowd arrival processes.
//! 3. **Multi-model gateway mixes** — two differently-shaped tenants
//!    (an MNIST-like and a HAR-like model, the serving-tier analogue of
//!    Fig. 8's application mix) share one fleet; the sweep crosses mix
//!    weights x replica counts and records per-model achieved rate,
//!    shed, p99, and the per-model conservation check.
//! 4. **Fairness under a skewed burst** — a 10:1 arrival skew toward a
//!    majority tenant, run under the pre-fair `Fixed` dispatch and
//!    under `FairSteal` (minority tenant service-weighted 4x). Recorded
//!    per dispatch: the minority tenant's p95 *queueing* delay (the
//!    starvation metric), stolen-batch counts, and the Jain fairness
//!    index over weight-normalized rows (raw + demand-normalized). The
//!    acceptance shape: fair dispatch improves the minority p95 queue
//!    delay vs fixed and steals > 0 batches under skew.
//! 5. **Admission quotas under the same burst** — quota-off vs quota-on
//!    (`QuotaPolicy::Weighted`, half the queue reserved by weight) on a
//!    small RejectNew queue, so admission is the bottleneck. Recorded
//!    per run: per-tenant shed rates, reserved slots, and the
//!    demand-normalized fairness index. The acceptance shape: the
//!    minority tenant's shed rate is lower with quotas on — reserved
//!    slots keep its arrivals admissible through the majority burst.
//! 6. **Telemetry spine overhead** — the closed-loop hammering rerun
//!    with the spine fully off vs on (collector thread + windowed stats
//!    + flight recorder + 1-in-64 span tracing). Recorded per mode:
//!    rows/sec, p50/p95/p99, and ring-overflow drops. The acceptance
//!    shape: spine-on throughput and p95 stay within 2% of off.
//! 7. **Network front door overhead** — the same closed-loop hammering
//!    driven in-process (`ModelHandle`) vs through the framed wire
//!    protocol (`NetServer` + `NetClient` on loopback TCP), per replica
//!    count. The p50 delta between the two paths is the per-request
//!    protocol cost: framing, two socket hops, and the client's
//!    correlation-id bookkeeping.
//! 8. **SLO-driven autoscaling** — time-varying arrivals (`diurnal` and
//!    `flash-crowd`) served by an elastic `1..peak` fleet (the
//!    `coordinator::autoscale` controller scaling on windowed telemetry
//!    signals) vs a fixed peak-size fleet. Recorded per run: SLO
//!    attainment (fraction of requests whose queueing delay met the p95
//!    target), worker-seconds consumed, scale-event count, and shed
//!    rate. The acceptance shape: on `diurnal`, the autoscaled fleet
//!    attains >= 95% of the SLO while consuming measurably fewer
//!    worker-seconds than the fixed peak fleet.
//!
//! ```bash
//! cargo bench --bench serving_scale
//! # or a subset, e.g. just the wire-protocol section:
//! KANSAS_BENCH_SECTIONS=net cargo bench --bench serving_scale
//! ```
//!
//! `KANSAS_BENCH_SECTIONS` takes a comma-separated list of section
//! names (`closed_loop`, `open_loop`, `multi_model`, `fairness`,
//! `quota`, `telemetry`, `net`, `autoscale`); unset or empty runs
//! everything.
//!
//! Besides the printed tables, the run writes `BENCH_serving.json`
//! (throughput per replica count, scenario shed rates, p50/p99 latency,
//! multi-model mix rows, fairness rows, quota rows, telemetry overhead
//! rows, wire-protocol overhead rows, autoscale SLO-vs-cost rows) so
//! the serving perf trajectory is
//! tracked across PRs instead of anecdotal. Sections are merge-appended
//! through `bench::write_artifact` — a partial rerun refreshes only its
//! own sections. The file is rendered by the deterministic `util::json`
//! writer and its validity is smoke-tested by `tests/bench_artifacts.rs`.

use std::collections::BTreeMap;
use std::time::Duration;

use kan_sas::arch::ArrayConfig;
use kan_sas::bench;
use kan_sas::coordinator::{
    AutoscaleConfig, BatchPolicy, Dispatch, GatewayBuilder, GatewayConfig, NetClient, NetConfig,
    NetServer, Pool, PoolConfig, QuotaPolicy, ShedPolicy, TelemetryConfig,
};
use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::loadgen::{self, Focus, MixEntry, Scenario};
use kan_sas::report::Table;
use kan_sas::util::json::Value;

fn bench_engine() -> Engine {
    // big enough that per-batch compute dominates queue/lock overhead
    Engine::new(QuantizedModel::synthetic("bench_kan", &[64, 128, 64, 10], 5, 3, 42))
}

/// Bench-grade telemetry: the serving-default spine stays on, but the
/// `Metrics` cells keep exact latency samples so reported percentiles
/// carry no histogram bucketing error.
fn bench_telemetry() -> TelemetryConfig {
    TelemetryConfig { exact_samples: true, ..TelemetryConfig::default() }
}

fn pool_config(replicas: usize, queue_cap: usize, shed: ShedPolicy) -> PoolConfig {
    PoolConfig {
        replicas,
        queue_cap,
        shed,
        policy: BatchPolicy { max_batch: 32, max_wait: Duration::from_micros(500) },
        sim_array: ArrayConfig::kan_sas(16, 16, 4, 8),
        dispatch: Dispatch::FairSteal,
        quota: QuotaPolicy::None,
        telemetry: bench_telemetry(),
        ..Default::default()
    }
}

/// `KANSAS_BENCH_SECTIONS="net,closed_loop"` runs just those sections;
/// unset (or blank) runs the full sweep.
fn section_enabled(name: &str) -> bool {
    match std::env::var("KANSAS_BENCH_SECTIONS") {
        Ok(list) if !list.trim().is_empty() => list.split(',').any(|s| s.trim() == name),
        _ => true,
    }
}

/// 1. closed-loop saturation sweep; fills `rows_at` (rows/s per replica
/// count) for the later sections' rate targets.
fn section_closed_loop(engine: &Engine, cores: usize, rows_at: &mut BTreeMap<usize, f64>) -> Value {
    let mut t = Table::new(&["replicas", "rows/s", "speedup", "req/s", "mean batch", "p50 us", "p99 us"])
        .with_title("closed-loop saturation (16 clients, 700ms, steady hammering)");
    let mut baseline_rows = 0.0f64;
    let mut closed_json = Vec::new();
    for &replicas in &[1usize, 2, 4, 8] {
        let pool = Pool::start(engine.clone(), pool_config(replicas, 4096, ShedPolicy::Block));
        let rep = loadgen::closed_loop(&pool.handle(), 16, Duration::from_millis(700), None, 7);
        let stats = pool.shutdown();
        let rows_s = stats.merged.batch_rows as f64 / rep.wall.as_secs_f64();
        if replicas == 1 {
            baseline_rows = rows_s;
        }
        rows_at.insert(replicas, rows_s);
        let (p50, p99) = rep.latency.map(|l| (l.p50_us, l.p99_us)).unwrap_or((0, 0));
        t.row(vec![
            replicas.to_string(),
            format!("{rows_s:.0}"),
            format!("{:.2}x", rows_s / baseline_rows.max(1.0)),
            format!("{:.0}", rep.achieved_rps),
            format!("{:.1}", stats.merged.mean_batch_size()),
            p50.to_string(),
            p99.to_string(),
        ]);
        closed_json.push(Value::obj([
            ("replicas", Value::num(replicas as f64)),
            ("rows_per_s", Value::num(rows_s)),
            ("speedup", Value::num(rows_s / baseline_rows.max(1.0))),
            ("achieved_rps", Value::num(rep.achieved_rps)),
            ("mean_batch", Value::num(stats.merged.mean_batch_size())),
            ("p50_us", Value::num(p50 as f64)),
            ("p99_us", Value::num(p99 as f64)),
        ]));
    }
    print!("{}", t.render());
    let x4 = rows_at.get(&4).copied().unwrap_or(0.0) / baseline_rows.max(1.0);
    println!(
        "4-replica scaling: {x4:.2}x rows/s vs 1 replica (target >= 2x; ideal bounded by {} cores)\n",
        cores
    );
    Value::arr(closed_json)
}

/// 2. open-loop scenario mixes on a fixed pool size.
fn section_open_loop(engine: &Engine, cores: usize, rows_at: &BTreeMap<usize, f64>) -> Value {
    let replicas = cores.clamp(2, 4);
    let rate = rows_at.get(&replicas).copied().unwrap_or(4000.0) * 0.6; // below saturation
    println!("open-loop scenarios ({replicas} replicas, headline rate {rate:.0} rps, RejectNew, queue 256):");
    let mut scenario_json = Vec::new();
    for name in ["steady", "diurnal", "flash-crowd"] {
        let pool = Pool::start(engine.clone(), pool_config(replicas, 256, ShedPolicy::RejectNew));
        let sc = Scenario::by_name(name, rate, Duration::from_millis(900)).unwrap();
        let rep = loadgen::run(&pool.handle(), &sc, 11);
        let stats = pool.shutdown();
        println!("  {}", rep.summary());
        let per: Vec<String> = stats
            .per_replica
            .iter()
            .enumerate()
            .map(|(i, m)| format!("r{i}: {} rows, {:.0}% util", m.batch_rows, 100.0 * m.sim_utilization()))
            .collect();
        println!(
            "    peak queue {:>4}  | {}",
            stats.peak_depth,
            per.join("  ")
        );
        let (p50, p99) = rep.latency.map(|l| (l.p50_us, l.p99_us)).unwrap_or((0, 0));
        scenario_json.push(Value::obj([
            ("scenario", Value::str(name)),
            ("offered_rps", Value::num(rep.offered_rps)),
            ("achieved_rps", Value::num(rep.achieved_rps)),
            ("ok", Value::num(rep.ok as f64)),
            ("shed", Value::num(rep.shed as f64)),
            ("shed_rate", Value::num(rep.shed_rate())),
            ("p50_us", Value::num(p50 as f64)),
            ("p99_us", Value::num(p99 as f64)),
            ("peak_queue", Value::num(stats.peak_depth as f64)),
        ]));
    }
    Value::arr(scenario_json)
}

/// 3. multi-model gateway: mix weights x replica counts on one fleet.
fn section_multi_model(rows_at: &BTreeMap<usize, f64>) -> Value {
    let mnist_like =
        Engine::new(QuantizedModel::synthetic("mnist_mix", &[64, 128, 64, 10], 5, 3, 42));
    let har_like = Engine::new(QuantizedModel::synthetic("har_mix", &[16, 32, 6], 5, 3, 43));
    let mix_rate = rows_at.get(&2).copied().unwrap_or(4000.0) * 0.6;
    println!(
        "\nmulti-model gateway (mnist_mix + har_mix, RejectNew, queue 256, {mix_rate:.0} rps):"
    );
    let mut t = Table::new(&[
        "replicas", "mix", "model", "offered", "achieved", "shed %", "p99 us", "conserved",
    ])
    .with_title("mix x replicas sweep (one fleet, per-model batchers)");
    let mut mix_json = Vec::new();
    for &replicas in &[2usize, 4] {
        for &(wa, wb) in &[(1.0f64, 1.0f64), (4.0, 1.0)] {
            let mut b = GatewayBuilder::with_config(GatewayConfig {
                replicas,
                queue_cap: 256,
                shed: ShedPolicy::RejectNew,
                policy: BatchPolicy { max_batch: 32, max_wait: Duration::from_micros(500) },
                sim_array: ArrayConfig::kan_sas(16, 16, 4, 8),
                dispatch: Dispatch::FairSteal,
                quota: QuotaPolicy::None,
                telemetry: bench_telemetry(),
                ..Default::default()
            });
            let a = b.register("mnist_mix", mnist_like.clone());
            let h = b.register("har_mix", har_like.clone());
            let gw = b.start();
            let entries = [
                MixEntry { handle: gw.handle(a), weight: wa },
                MixEntry { handle: gw.handle(h), weight: wb },
            ];
            let sc = Scenario::steady(mix_rate, Duration::from_millis(700));
            let mix = loadgen::run_mix(&entries, &sc, 13);
            let stats = gw.shutdown();
            let mix_label = format!("{wa:.0}:{wb:.0}");
            let mut per_model_json = Vec::new();
            for (rep, ms) in mix.per_model.iter().zip(&stats.per_model) {
                let p99 = rep.latency.map(|l| l.p99_us).unwrap_or(0);
                t.row(vec![
                    replicas.to_string(),
                    mix_label.clone(),
                    rep.scenario.clone(),
                    format!("{:.0}", rep.offered_rps),
                    format!("{:.0}", rep.achieved_rps),
                    format!("{:.1}", 100.0 * rep.shed_rate()),
                    p99.to_string(),
                    if ms.conserved() { "yes".into() } else { "NO".into() },
                ]);
                per_model_json.push(Value::obj([
                    ("model", Value::str(rep.scenario.clone())),
                    ("offered_rps", Value::num(rep.offered_rps)),
                    ("achieved_rps", Value::num(rep.achieved_rps)),
                    ("ok", Value::num(rep.ok as f64)),
                    ("shed", Value::num(rep.shed as f64)),
                    ("shed_rate", Value::num(rep.shed_rate())),
                    ("p99_us", Value::num(p99 as f64)),
                    ("mean_queue_us", Value::num(ms.metrics.mean_queue_us())),
                    ("mean_service_us", Value::num(ms.metrics.mean_service_us())),
                    ("conserved", Value::num(if ms.conserved() { 1.0 } else { 0.0 })),
                ]));
            }
            mix_json.push(Value::obj([
                ("replicas", Value::num(replicas as f64)),
                ("mix", Value::str(mix_label)),
                ("offered_rps", Value::num(mix.total.offered_rps)),
                ("achieved_rps", Value::num(mix.total.achieved_rps)),
                ("peak_queue", Value::num(stats.peak_depth as f64)),
                ("per_model", Value::arr(per_model_json)),
            ]));
        }
    }
    print!("{}", t.render());
    Value::arr(mix_json)
}

/// 4. fairness under a 10:1 skewed burst: pre-fair fixed dispatch vs
/// weighted DRR + work stealing. Both tenants share a shape, so the
/// minority tenant's p95 queue delay isolates *dispatch* fairness
/// (not service-cost asymmetry); the burst runs well past saturation
/// so head-of-line blocking actually bites under fixed dispatch.
fn section_fairness(cores: usize, rows_at: &BTreeMap<usize, f64>) -> Value {
    let majority = Engine::new(QuantizedModel::synthetic("majority", &[64, 128, 64, 10], 5, 3, 42));
    let minority = Engine::new(QuantizedModel::synthetic("minority", &[64, 128, 64, 10], 5, 3, 44));
    let fair_replicas = cores.clamp(2, 4);
    let sat = rows_at.get(&fair_replicas).copied().unwrap_or(4000.0);
    let skew_sc = Scenario::skewed_burst(
        sat * 0.7,
        4.0, // burst at ~2.8x saturation
        Duration::from_millis(900),
        Focus { entry: 0, share: 10.0 / 11.0 },
    );
    println!(
        "\nfairness under skewed burst ({fair_replicas} replicas, base {:.0} rps, 4x burst, 10:1 on majority):",
        sat * 0.7
    );
    let mut t = Table::new(&[
        "dispatch", "model", "wt", "offered", "achieved", "shed %", "q p95 us", "stolen",
        "fairness", "conserved",
    ])
    .with_title("fixed vs fair-steal dispatch (minority tenant weighted 4x under fair)");
    let mut fairness_json = Vec::new();
    for (label, dispatch, w_major, w_minor) in
        [("fixed", Dispatch::Fixed, 1u32, 1u32), ("fair-steal", Dispatch::FairSteal, 1, 4)]
    {
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas: fair_replicas,
            queue_cap: 512,
            shed: ShedPolicy::RejectNew,
            policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(500) },
            sim_array: ArrayConfig::kan_sas(16, 16, 4, 8),
            dispatch,
            quota: QuotaPolicy::None,
            telemetry: bench_telemetry(),
            ..Default::default()
        });
        let maj = b.register_weighted("majority", majority.clone(), w_major);
        let min = b.register_weighted("minority", minority.clone(), w_minor);
        let gw = b.start();
        let entries = [
            MixEntry { handle: gw.handle(maj), weight: 10.0 },
            MixEntry { handle: gw.handle(min), weight: 1.0 },
        ];
        let mix = loadgen::run_mix(&entries, &skew_sc, 23);
        let stats = gw.shutdown();
        let fairness = stats.fairness_index();
        let fairness_norm = stats.fairness_index_normalized();
        let stolen = stats.stolen_batches();
        let mut per_model_json = Vec::new();
        for (rep, ms) in mix.per_model.iter().zip(&stats.per_model) {
            let q95 = ms.metrics.queue_latency().map(|l| l.p95_us).unwrap_or(0);
            t.row(vec![
                label.to_string(),
                rep.scenario.clone(),
                ms.weight.to_string(),
                format!("{:.0}", rep.offered_rps),
                format!("{:.0}", rep.achieved_rps),
                format!("{:.1}", 100.0 * rep.shed_rate()),
                q95.to_string(),
                ms.metrics.stolen_batches.to_string(),
                format!("{fairness:.3}"),
                if ms.conserved() { "yes".into() } else { "NO".into() },
            ]);
            per_model_json.push(Value::obj([
                ("model", Value::str(rep.scenario.clone())),
                ("weight", Value::num(ms.weight as f64)),
                ("offered_rps", Value::num(rep.offered_rps)),
                ("achieved_rps", Value::num(rep.achieved_rps)),
                ("ok", Value::num(rep.ok as f64)),
                ("shed", Value::num(rep.shed as f64)),
                ("shed_rate", Value::num(rep.shed_rate())),
                ("p95_queue_us", Value::num(q95 as f64)),
                ("mean_queue_us", Value::num(ms.metrics.mean_queue_us())),
                ("stolen_batches", Value::num(ms.metrics.stolen_batches as f64)),
                ("conserved", Value::num(if ms.conserved() { 1.0 } else { 0.0 })),
            ]));
        }
        let minority_q95 = stats.per_model[1]
            .metrics
            .queue_latency()
            .map(|l| l.p95_us)
            .unwrap_or(0);
        println!(
            "  {label:<10} fairness {fairness:.3} (norm {fairness_norm:.3})  stolen {stolen:>4}  minority p95 queue {minority_q95} us"
        );
        fairness_json.push(Value::obj([
            ("dispatch", Value::str(label)),
            ("replicas", Value::num(fair_replicas as f64)),
            ("scenario", Value::str(skew_sc.name.clone())),
            ("offered_rps", Value::num(mix.total.offered_rps)),
            ("achieved_rps", Value::num(mix.total.achieved_rps)),
            ("fairness_index", Value::num(fairness)),
            ("fairness_normalized", Value::num(fairness_norm)),
            ("stolen_batches", Value::num(stolen as f64)),
            ("minority_p95_queue_us", Value::num(minority_q95 as f64)),
            ("per_model", Value::arr(per_model_json)),
        ]));
    }
    print!("{}", t.render());
    println!(
        "acceptance shape: fair-steal minority p95 queue < fixed, stolen_batches > 0 under skew"
    );
    Value::arr(fairness_json)
}

/// 5. per-tenant admission quotas under the same 10:1 skewed burst:
/// quota-off vs quota-on SHED fairness. A small RejectNew queue makes
/// admission (not dispatch) the bottleneck, so the majority burst
/// fills the whole queue and sheds the minority's arrivals too —
/// unless weighted reservations hold slots open for it. Acceptance
/// shape: with quotas on, the minority tenant's shed rate drops.
fn section_quota(cores: usize, rows_at: &BTreeMap<usize, f64>) -> Value {
    let majority = Engine::new(QuantizedModel::synthetic("majority", &[64, 128, 64, 10], 5, 3, 42));
    let minority = Engine::new(QuantizedModel::synthetic("minority", &[64, 128, 64, 10], 5, 3, 44));
    let quota_replicas = cores.clamp(2, 4);
    let qsat = rows_at.get(&quota_replicas).copied().unwrap_or(4000.0);
    let quota_sc = Scenario::skewed_burst(
        qsat * 0.7,
        4.0,
        Duration::from_millis(900),
        Focus { entry: 0, share: 10.0 / 11.0 },
    );
    println!(
        "\nadmission quotas under skewed burst ({quota_replicas} replicas, queue 128, RejectNew, minority weighted 4x):"
    );
    let mut t = Table::new(&[
        "quota", "model", "wt", "rsvd", "offered", "shed %", "q p95 us", "norm fair", "conserved",
    ])
    .with_title("quota-off vs quota-on shed fairness (10:1 burst on the majority)");
    let mut quota_json = Vec::new();
    let mut minority_shed = [0.0f64; 2];
    for (qi, (label, quota)) in
        [("off", QuotaPolicy::None), ("on", QuotaPolicy::Weighted { reserve: 0.5 })]
            .into_iter()
            .enumerate()
    {
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas: quota_replicas,
            queue_cap: 128,
            shed: ShedPolicy::RejectNew,
            policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(500) },
            sim_array: ArrayConfig::kan_sas(16, 16, 4, 8),
            dispatch: Dispatch::FairSteal,
            quota,
            telemetry: bench_telemetry(),
            ..Default::default()
        });
        let maj = b.register_weighted("majority", majority.clone(), 1);
        let min = b.register_weighted("minority", minority.clone(), 4);
        let gw = b.start();
        let entries = [
            MixEntry { handle: gw.handle(maj), weight: 10.0 },
            MixEntry { handle: gw.handle(min), weight: 1.0 },
        ];
        let mix = loadgen::run_mix(&entries, &quota_sc, 37);
        let stats = gw.shutdown();
        let norm = stats.fairness_index_normalized();
        let mut per_model_json = Vec::new();
        for (rep, ms) in mix.per_model.iter().zip(&stats.per_model) {
            let q95 = ms.metrics.queue_latency().map(|l| l.p95_us).unwrap_or(0);
            t.row(vec![
                label.to_string(),
                rep.scenario.clone(),
                ms.weight.to_string(),
                ms.reserved.to_string(),
                format!("{:.0}", rep.offered_rps),
                format!("{:.1}", 100.0 * rep.shed_rate()),
                q95.to_string(),
                format!("{norm:.3}"),
                if ms.conserved() { "yes".into() } else { "NO".into() },
            ]);
            per_model_json.push(Value::obj([
                ("model", Value::str(rep.scenario.clone())),
                ("weight", Value::num(ms.weight as f64)),
                ("reserved_slots", Value::num(ms.reserved as f64)),
                ("offered_rps", Value::num(rep.offered_rps)),
                ("ok", Value::num(rep.ok as f64)),
                ("shed", Value::num(rep.shed as f64)),
                ("shed_rate", Value::num(rep.shed_rate())),
                ("p95_queue_us", Value::num(q95 as f64)),
                ("conserved", Value::num(if ms.conserved() { 1.0 } else { 0.0 })),
            ]));
        }
        minority_shed[qi] = mix.per_model[1].shed_rate();
        println!(
            "  quota {label:<4} minority shed {:.1}%  majority shed {:.1}%  norm fairness {norm:.3}",
            100.0 * mix.per_model[1].shed_rate(),
            100.0 * mix.per_model[0].shed_rate(),
        );
        quota_json.push(Value::obj([
            ("quota", Value::str(label)),
            ("replicas", Value::num(quota_replicas as f64)),
            ("queue_cap", Value::num(128.0)),
            ("scenario", Value::str(quota_sc.name.clone())),
            ("offered_rps", Value::num(mix.total.offered_rps)),
            ("achieved_rps", Value::num(mix.total.achieved_rps)),
            ("fairness_normalized", Value::num(norm)),
            ("minority_shed_rate", Value::num(mix.per_model[1].shed_rate())),
            ("majority_shed_rate", Value::num(mix.per_model[0].shed_rate())),
            ("registry_epoch", Value::num(stats.epoch as f64)),
            ("per_model", Value::arr(per_model_json)),
        ]));
    }
    print!("{}", t.render());
    println!(
        "acceptance shape: minority shed rate with quotas on ({:.1}%) < off ({:.1}%)",
        100.0 * minority_shed[1],
        100.0 * minority_shed[0]
    );
    Value::arr(quota_json)
}

/// 6. telemetry spine overhead: the same closed-loop hammering with
/// the spine fully off vs on (windowed collector + flight recorder +
/// 1-in-64 span tracing — a harsher setting than the serving
/// default). Acceptance shape: rows/s and p95 within 2% of off.
fn section_telemetry(engine: &Engine, cores: usize) -> Value {
    let tel_replicas = cores.clamp(2, 4);
    println!("\ntelemetry overhead ({tel_replicas} replicas, 16 clients, 700ms, spine off vs on):");
    let mut t = Table::new(&[
        "telemetry", "rows/s", "req/s", "p50 us", "p95 us", "p99 us", "dropped",
    ])
    .with_title("spine off vs on (windowed stats + flight recorder + 1-in-64 spans)");
    let mut telemetry_json = Vec::new();
    let mut tel_rows = [0.0f64; 2];
    let mut tel_p95 = [0u64; 2];
    for (ti, (label, tcfg)) in [
        ("off", TelemetryConfig::off()),
        ("on", TelemetryConfig { trace_sample: 64, ..TelemetryConfig::default() }),
    ]
    .into_iter()
    .enumerate()
    {
        let mut cfg = pool_config(tel_replicas, 4096, ShedPolicy::Block);
        cfg.telemetry = TelemetryConfig { exact_samples: true, ..tcfg };
        let pool = Pool::start(engine.clone(), cfg);
        let tel = pool.telemetry();
        let rep = loadgen::closed_loop(&pool.handle(), 16, Duration::from_millis(700), None, 7);
        let stats = pool.shutdown();
        let dropped = tel.dropped_events();
        let rows_s = stats.merged.batch_rows as f64 / rep.wall.as_secs_f64();
        let (p50, p95, p99) =
            rep.latency.map(|l| (l.p50_us, l.p95_us, l.p99_us)).unwrap_or((0, 0, 0));
        tel_rows[ti] = rows_s;
        tel_p95[ti] = p95;
        t.row(vec![
            label.to_string(),
            format!("{rows_s:.0}"),
            format!("{:.0}", rep.achieved_rps),
            p50.to_string(),
            p95.to_string(),
            p99.to_string(),
            dropped.to_string(),
        ]);
        telemetry_json.push(Value::obj([
            ("mode", Value::str(label)),
            ("replicas", Value::num(tel_replicas as f64)),
            ("rows_per_s", Value::num(rows_s)),
            ("achieved_rps", Value::num(rep.achieved_rps)),
            ("p50_us", Value::num(p50 as f64)),
            ("p95_us", Value::num(p95 as f64)),
            ("p99_us", Value::num(p99 as f64)),
            ("dropped_events", Value::num(dropped as f64)),
        ]));
    }
    print!("{}", t.render());
    let rows_delta = (tel_rows[0] - tel_rows[1]) / tel_rows[0].max(1.0);
    let p95_delta =
        (tel_p95[1] as f64 - tel_p95[0] as f64) / (tel_p95[0] as f64).max(1.0);
    println!(
        "acceptance shape: spine-on within 2% of off (throughput delta {:.2}%, p95 delta {:.2}%)",
        100.0 * rows_delta,
        100.0 * p95_delta
    );
    Value::arr(telemetry_json)
}

/// 7. network front door: the closed-loop hammering driven in-process
/// (`ModelHandle`) vs through the framed wire protocol (`NetServer` +
/// `NetClient` over loopback TCP) against an identically configured
/// gateway. The p50 delta at equal replicas is the per-request protocol
/// cost: header+payload framing, two socket hops, and the client's
/// correlation-id multiplexing.
fn section_net(engine: &Engine, cores: usize) -> Value {
    let net_replicas = cores.clamp(2, 4);
    println!(
        "\nnetwork front door overhead ({net_replicas} replicas, 8 clients, 500ms, loopback TCP):"
    );
    let mut t = Table::new(&[
        "path", "replicas", "rows/s", "req/s", "p50 us", "p99 us", "p50 + us",
    ])
    .with_title("in-process ModelHandle vs NetClient over 127.0.0.1 (same gateway config)");
    let mut net_json = Vec::new();
    for &replicas in &[1usize, net_replicas] {
        let mut p50_direct = 0u64;
        for path in ["in-process", "net"] {
            let mut b = GatewayBuilder::with_config(GatewayConfig {
                replicas,
                queue_cap: 4096,
                shed: ShedPolicy::Block,
                policy: BatchPolicy { max_batch: 32, max_wait: Duration::from_micros(500) },
                sim_array: ArrayConfig::kan_sas(16, 16, 4, 8),
                dispatch: Dispatch::FairSteal,
                quota: QuotaPolicy::None,
                telemetry: bench_telemetry(),
                ..Default::default()
            });
            let id = b.register("bench_kan", engine.clone());
            let gw = b.start();
            let rep = if path == "net" {
                let server = NetServer::start("127.0.0.1:0", &gw, NetConfig::default())
                    .expect("loopback listener");
                let client = NetClient::connect(&server.local_addr().to_string())
                    .expect("loopback client");
                let handle = client.handle("bench_kan").expect("registered model");
                let rep = loadgen::closed_loop(&handle, 8, Duration::from_millis(500), None, 7);
                client.close();
                server.shutdown();
                rep
            } else {
                loadgen::closed_loop(&gw.handle(id), 8, Duration::from_millis(500), None, 7)
            };
            let stats = gw.shutdown();
            let rows_s = stats.merged.batch_rows as f64 / rep.wall.as_secs_f64();
            let (p50, p99) = rep.latency.map(|l| (l.p50_us, l.p99_us)).unwrap_or((0, 0));
            let overhead_us = if path == "net" {
                p50.saturating_sub(p50_direct)
            } else {
                p50_direct = p50;
                0
            };
            t.row(vec![
                path.to_string(),
                replicas.to_string(),
                format!("{rows_s:.0}"),
                format!("{:.0}", rep.achieved_rps),
                p50.to_string(),
                p99.to_string(),
                if path == "net" { format!("+{overhead_us}") } else { "-".to_string() },
            ]);
            net_json.push(Value::obj([
                ("path", Value::str(path)),
                ("replicas", Value::num(replicas as f64)),
                ("rows_per_s", Value::num(rows_s)),
                ("achieved_rps", Value::num(rep.achieved_rps)),
                ("ok", Value::num(rep.ok as f64)),
                ("p50_us", Value::num(p50 as f64)),
                ("p99_us", Value::num(p99 as f64)),
                ("p50_overhead_us", Value::num(overhead_us as f64)),
            ]));
        }
    }
    print!("{}", t.render());
    println!(
        "protocol cost = net p50 - in-process p50 at equal replicas (loopback, one connection)"
    );
    Value::arr(net_json)
}

/// 8. SLO-driven autoscaling: time-varying arrivals served by an
/// elastic `1..peak` fleet vs a fixed peak-size fleet. The elastic
/// fleet starts at one worker; the real-clock autoscaler thread reads
/// 100ms telemetry windows every 50ms, doubles on a p95 queueing-delay
/// breach, and drains one worker after two calm windows. Scored on SLO
/// attainment (fraction of requests whose queueing delay was within the
/// p95 target — exact samples, no histogram error) against the
/// worker-seconds each fleet consumed.
fn section_autoscale(engine: &Engine, cores: usize, rows_at: &BTreeMap<usize, f64>) -> Value {
    let peak = cores.clamp(2, 4);
    let slo_us: u64 = 10_000;
    let sat = rows_at.get(&peak).copied().unwrap_or(4000.0);
    let rate = sat * 0.45; // peaks stress the fleet, troughs let it shrink
    println!(
        "\nautoscale (elastic 1..{peak} workers vs fixed {peak}, SLO p95 queue <= {slo_us} us, base {rate:.0} rps):"
    );
    let mut t = Table::new(&[
        "scenario", "fleet", "offered", "achieved", "shed %", "q p95 us", "SLO att %",
        "worker-s", "events",
    ])
    .with_title("SLO attainment vs worker-seconds (fixed peak fleet vs autoscaled)");
    let mut auto_json = Vec::new();
    for name in ["diurnal", "flash-crowd"] {
        let sc = Scenario::by_name(name, rate, Duration::from_millis(1500)).unwrap();
        let mut fixed_ws = 0.0f64;
        let mut auto_ws = 0.0f64;
        let mut auto_att = 0.0f64;
        for fleet in ["fixed-peak", "autoscaled"] {
            let mut cfg = pool_config(peak, 1024, ShedPolicy::RejectNew);
            // short windows + a fast evaluation interval so the
            // controller sees the arrival shape inside a 1.5s run
            cfg.telemetry = TelemetryConfig {
                exact_samples: true,
                window: Duration::from_millis(100),
                ..TelemetryConfig::default()
            };
            if fleet == "autoscaled" {
                cfg.autoscale = Some(AutoscaleConfig {
                    min_workers: 1,
                    max_workers: peak,
                    slo_p95_us: slo_us,
                    calm_windows: 2,
                    interval: Duration::from_millis(50),
                    ..AutoscaleConfig::default()
                });
            }
            let mut b = GatewayBuilder::with_config(cfg);
            let id = b.register("bench_kan", engine.clone());
            let gw = b.start();
            let rep = loadgen::run(&gw.handle(id), &sc, 29);
            let worker_us = gw.worker_time_us();
            let events = gw.scale_events();
            let stats = gw.shutdown();
            let attainment = stats.merged.queue_within_us(slo_us);
            let q95 = stats.merged.queue_latency().map(|l| l.p95_us).unwrap_or(0);
            let ws = worker_us as f64 / 1e6;
            if fleet == "fixed-peak" {
                fixed_ws = ws;
            } else {
                auto_ws = ws;
                auto_att = attainment;
            }
            t.row(vec![
                name.to_string(),
                fleet.to_string(),
                format!("{:.0}", rep.offered_rps),
                format!("{:.0}", rep.achieved_rps),
                format!("{:.1}", 100.0 * rep.shed_rate()),
                q95.to_string(),
                format!("{:.1}", 100.0 * attainment),
                format!("{ws:.2}"),
                events.len().to_string(),
            ]);
            auto_json.push(Value::obj([
                ("scenario", Value::str(name)),
                ("fleet", Value::str(fleet)),
                ("min_workers", Value::num(if fleet == "autoscaled" { 1.0 } else { peak as f64 })),
                ("max_workers", Value::num(peak as f64)),
                ("slo_p95_us", Value::num(slo_us as f64)),
                ("offered_rps", Value::num(rep.offered_rps)),
                ("achieved_rps", Value::num(rep.achieved_rps)),
                ("shed_rate", Value::num(rep.shed_rate())),
                ("p95_queue_us", Value::num(q95 as f64)),
                ("slo_attainment", Value::num(attainment)),
                ("worker_seconds", Value::num(ws)),
                ("scale_events", Value::num(events.len() as f64)),
                ("conserved", Value::num(if stats.per_model[0].conserved() { 1.0 } else { 0.0 })),
            ]));
        }
        println!(
            "  {name:<12} autoscaled: {:.1}% SLO attainment, {auto_ws:.2} worker-s vs fixed {fixed_ws:.2} ({:.0}% saved)",
            100.0 * auto_att,
            100.0 * (fixed_ws - auto_ws) / fixed_ws.max(1e-9),
        );
    }
    print!("{}", t.render());
    println!(
        "acceptance shape: diurnal autoscaled attainment >= 95% with worker-seconds < fixed peak"
    );
    Value::arr(auto_json)
}

fn main() {
    let engine = bench_engine();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "serving_scale — model {} ({} KiB weights, Arc-shared: pool memory ~flat in replicas), {} cores\n",
        engine.model.name,
        engine.param_bytes() / 1024,
        cores
    );

    // top-level artifact sections, gathered as sections run so a
    // partial (KANSAS_BENCH_SECTIONS-gated) sweep merge-appends only
    // what it measured into BENCH_serving.json
    let mut sections: Vec<(&'static str, Value)> = vec![
        ("bench", Value::str("serving_scale")),
        ("model", Value::str(engine.model.name.clone())),
        ("param_bytes", Value::num(engine.param_bytes() as f64)),
        ("cores", Value::num(cores as f64)),
    ];
    let mut rows_at = BTreeMap::new();
    if section_enabled("closed_loop") {
        sections.push(("closed_loop", section_closed_loop(&engine, cores, &mut rows_at)));
    }
    if section_enabled("open_loop") {
        sections.push(("open_loop", section_open_loop(&engine, cores, &rows_at)));
    }
    if section_enabled("multi_model") {
        sections.push(("multi_model", section_multi_model(&rows_at)));
    }
    if section_enabled("fairness") {
        sections.push(("fairness", section_fairness(cores, &rows_at)));
    }
    if section_enabled("quota") {
        sections.push(("quota", section_quota(cores, &rows_at)));
    }
    if section_enabled("telemetry") {
        sections.push(("telemetry", section_telemetry(&engine, cores)));
    }
    if section_enabled("net") {
        sections.push(("net", section_net(&engine, cores)));
    }
    if section_enabled("autoscale") {
        sections.push(("autoscale", section_autoscale(&engine, cores, &rows_at)));
    }

    let out = "BENCH_serving.json";
    bench::write_artifact(out, Value::obj(sections)).expect("write bench artifact");
    println!("wrote {out} (sections merge-appended)");
}
