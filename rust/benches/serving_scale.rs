//! Serving-pool scale sweep: replica count x offered load.
//!
//! Two measurements, both on a synthetic model (offline, no artifacts):
//!
//! 1. **Closed-loop saturation** per replica count — peak rows/sec with
//!    16 hammering clients. The acceptance bar is >= 2x rows/sec at 4
//!    replicas vs 1 on the steady load; weights stay one Arc-shared
//!    allocation, so pool memory is ~flat in replica count (printed, and
//!    asserted by `clones_alias_one_weight_allocation` in kan::engine).
//! 2. **Open-loop scenario mixes** at fixed replicas — offered vs
//!    achieved rate, shed rate, and tail latency for steady / diurnal /
//!    flash-crowd arrival processes.
//!
//! ```bash
//! cargo bench --bench serving_scale
//! ```
//!
//! Besides the printed tables, the run writes `BENCH_serving.json`
//! (throughput per replica count, scenario shed rates, p50/p99 latency)
//! so the serving perf trajectory is tracked across PRs instead of
//! anecdotal.

use std::time::Duration;

use kan_sas::arch::ArrayConfig;
use kan_sas::coordinator::{BatchPolicy, Pool, PoolConfig, ShedPolicy};
use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::loadgen::{self, Scenario};
use kan_sas::report::Table;
use kan_sas::util::json::Value;

fn bench_engine() -> Engine {
    // big enough that per-batch compute dominates queue/lock overhead
    Engine::new(QuantizedModel::synthetic("bench_kan", &[64, 128, 64, 10], 5, 3, 42))
}

fn pool_config(replicas: usize, queue_cap: usize, shed: ShedPolicy) -> PoolConfig {
    PoolConfig {
        replicas,
        queue_cap,
        shed,
        policy: BatchPolicy { max_batch: 32, max_wait: Duration::from_micros(500) },
        sim_array: ArrayConfig::kan_sas(16, 16, 4, 8),
    }
}

fn main() {
    let engine = bench_engine();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "serving_scale — model {} ({} KiB weights, Arc-shared: pool memory ~flat in replicas), {} cores\n",
        engine.model.name,
        engine.param_bytes() / 1024,
        cores
    );

    // 1. closed-loop saturation sweep
    let mut t = Table::new(&["replicas", "rows/s", "speedup", "req/s", "mean batch", "p50 us", "p99 us"])
        .with_title("closed-loop saturation (16 clients, 700ms, steady hammering)");
    let mut baseline_rows = 0.0f64;
    let mut rows_at = std::collections::BTreeMap::new();
    let mut closed_json = Vec::new();
    for &replicas in &[1usize, 2, 4, 8] {
        let pool = Pool::start(engine.clone(), pool_config(replicas, 4096, ShedPolicy::Block));
        let rep = loadgen::closed_loop(&pool.handle(), 16, Duration::from_millis(700), None, 7);
        let stats = pool.shutdown();
        let rows_s = stats.merged.batch_rows as f64 / rep.wall.as_secs_f64();
        if replicas == 1 {
            baseline_rows = rows_s;
        }
        rows_at.insert(replicas, rows_s);
        let (p50, p99) = rep.latency.map(|l| (l.p50_us, l.p99_us)).unwrap_or((0, 0));
        t.row(vec![
            replicas.to_string(),
            format!("{rows_s:.0}"),
            format!("{:.2}x", rows_s / baseline_rows.max(1.0)),
            format!("{:.0}", rep.achieved_rps),
            format!("{:.1}", stats.merged.mean_batch_size()),
            p50.to_string(),
            p99.to_string(),
        ]);
        closed_json.push(Value::obj([
            ("replicas", Value::num(replicas as f64)),
            ("rows_per_s", Value::num(rows_s)),
            ("speedup", Value::num(rows_s / baseline_rows.max(1.0))),
            ("achieved_rps", Value::num(rep.achieved_rps)),
            ("mean_batch", Value::num(stats.merged.mean_batch_size())),
            ("p50_us", Value::num(p50 as f64)),
            ("p99_us", Value::num(p99 as f64)),
        ]));
    }
    print!("{}", t.render());
    let x4 = rows_at.get(&4).copied().unwrap_or(0.0) / baseline_rows.max(1.0);
    println!(
        "4-replica scaling: {x4:.2}x rows/s vs 1 replica (target >= 2x; ideal bounded by {} cores)\n",
        cores
    );

    // 2. open-loop scenario mixes on a fixed pool size
    let replicas = cores.clamp(2, 4);
    let rate = rows_at.get(&replicas).copied().unwrap_or(4000.0) * 0.6; // below saturation
    println!("open-loop scenarios ({replicas} replicas, headline rate {rate:.0} rps, RejectNew, queue 256):");
    let mut scenario_json = Vec::new();
    for name in ["steady", "diurnal", "flash-crowd"] {
        let pool = Pool::start(engine.clone(), pool_config(replicas, 256, ShedPolicy::RejectNew));
        let sc = Scenario::by_name(name, rate, Duration::from_millis(900)).unwrap();
        let rep = loadgen::run(&pool.handle(), &sc, 11);
        let stats = pool.shutdown();
        println!("  {}", rep.summary());
        let per: Vec<String> = stats
            .per_replica
            .iter()
            .enumerate()
            .map(|(i, m)| format!("r{i}: {} rows, {:.0}% util", m.batch_rows, 100.0 * m.sim_utilization()))
            .collect();
        println!(
            "    peak queue {:>4}  | {}",
            stats.peak_depth,
            per.join("  ")
        );
        let (p50, p99) = rep.latency.map(|l| (l.p50_us, l.p99_us)).unwrap_or((0, 0));
        scenario_json.push(Value::obj([
            ("scenario", Value::str(name)),
            ("offered_rps", Value::num(rep.offered_rps)),
            ("achieved_rps", Value::num(rep.achieved_rps)),
            ("ok", Value::num(rep.ok as f64)),
            ("shed", Value::num(rep.shed as f64)),
            ("shed_rate", Value::num(rep.shed_rate())),
            ("p50_us", Value::num(p50 as f64)),
            ("p99_us", Value::num(p99 as f64)),
            ("peak_queue", Value::num(stats.peak_depth as f64)),
        ]));
    }

    let doc = Value::obj([
        ("bench", Value::str("serving_scale")),
        ("model", Value::str(engine.model.name.clone())),
        ("param_bytes", Value::num(engine.param_bytes() as f64)),
        ("cores", Value::num(cores as f64)),
        ("closed_loop", Value::arr(closed_json)),
        ("open_loop", Value::arr(scenario_json)),
    ]);
    let out = "BENCH_serving.json";
    std::fs::write(out, doc.render() + "\n").expect("write bench artifact");
    println!("wrote {out}");
}
