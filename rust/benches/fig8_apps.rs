//! Bench: regenerate Fig. 8 (per-application utilization at similar area)
//! and time the per-app simulation.

use kan_sas::bench::bench_val;
use kan_sas::experiments;

fn main() {
    let (t, avg, _) = experiments::fig8();
    print!("{}", t.render());
    println!("average absolute improvement: {avg:.1} pp (paper: 39.9)\n");
    bench_val("fig8 per-app simulation", experiments::fig8);
}
