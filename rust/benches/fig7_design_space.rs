//! Bench: regenerate Fig. 7a (utilization vs area) and Fig. 7b (runtime
//! vs area) and time the full design-space sweep.

use kan_sas::bench::bench_val;
use kan_sas::experiments;

fn main() {
    let (a, b) = experiments::fig7(Some(std::path::Path::new("bench_out")));
    println!("{a}");
    println!("{b}");
    println!(
        "equal-area cycle ratio (conv 32x32 / KAN-SAs 16x16): {:.2}x (paper: ~2x)",
        experiments::equal_area_cycle_ratio()
    );
    println!("\n=== sweep wallclock (both families, all sizes, all apps) ===");
    bench_val("fig7 full design-space sweep", || {
        (experiments::fig7_sweep(false), experiments::fig7_sweep(true))
    });
}
