//! Bench: regenerate Table I (PE delay/power/normalized-energy) and time
//! the functional PE models' hot loops.

use kan_sas::arch::{ScalarPe, VectorPe};
use kan_sas::bench::bench_val;
use kan_sas::experiments;
use kan_sas::util::rng::Rng;

fn main() {
    println!("=== Table I regeneration ===");
    print!("{}", experiments::table1().render());

    println!("=== functional PE throughput (simulator hot loop) ===");
    let mut rng = Rng::new(1);
    let acts: Vec<u8> = (0..65536).map(|_| 1 + rng.below(255) as u8).collect();

    let mut spe = ScalarPe::default();
    spe.load(37);
    bench_val("scalar PE: 64k MACs", || {
        let mut psum = 0i32;
        for &a in &acts {
            psum = spe.step(a, psum);
        }
        psum
    });

    let mut vpe = VectorPe::new(4, 8);
    vpe.load(&[1, -2, 3, -4, 5, -6, 7, -8]);
    let vals: Vec<[u8; 4]> = (0..16384)
        .map(|_| [0; 4].map(|_| 1 + rng.below(255) as u8))
        .collect();
    let ks: Vec<usize> = (0..16384).map(|_| 3 + rng.below(5)).collect();
    bench_val("4:8 vector PE: 16k vector-MACs (64k lanes)", || {
        let mut psum = 0i32;
        for (v, &k) in vals.iter().zip(&ks) {
            psum = vpe.step_kan(v, k, psum);
        }
        psum
    });
}
