//! Offline stand-in for the `anyhow` crate.
//!
//! The build image has no registry access, so this crate provides the
//! subset of anyhow's API the workspace actually uses — `Error`,
//! `Result<T>`, `Context` on both `Result` and `Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros — with the same semantics at
//! those call sites (context prepends `"{ctx}: {cause}"`, `?` converts
//! any `std::error::Error`, ties to the real crate's macro grammar).
//! Errors carry a rendered message only; no backtraces or source chains.

use std::fmt;

/// A rendered error message (anyhow's `Error`, minus backtraces).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (anyhow's `Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }

    /// Prepend a context layer: `"{ctx}: {self}"`.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` conversion from any std error. `Error` itself deliberately does NOT
// implement `std::error::Error`, exactly like the real anyhow, so this
// blanket impl cannot overlap the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error/`None` arm of a `Result` or `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let lit = anyhow!("plain");
        assert_eq!(lit.to_string(), "plain");
        let x = 7;
        let cap = anyhow!("x = {x}");
        assert_eq!(cap.to_string(), "x = 7");
        let args = anyhow!("{} + {}", 1, 2);
        assert_eq!(args.to_string(), "1 + 2");
        let from_string = anyhow!(String::from("owned"));
        assert_eq!(from_string.to_string(), "owned");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            let n = 3;
            ensure!(n > 2);
            if n == 99 {
                bail!("unreachable {n}");
            }
            Ok(n)
        }
        assert_eq!(f(true).unwrap(), 3);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading x").unwrap_err();
        assert_eq!(e.to_string(), "reading x: gone");

        let o: Option<u8> = None;
        let e = o.with_context(|| format!("missing {}", "y")).unwrap_err();
        assert_eq!(e.to_string(), "missing y");
        assert_eq!(Some(5u8).context("fine").unwrap(), 5);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err::<(), std::io::Error>(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn collect_with_default_param() {
        let v: Result<Vec<u32>> = ["1", "2"].iter().map(|s| s.parse::<u32>().context("num")).collect();
        assert_eq!(v.unwrap(), vec![1, 2]);
    }
}
