"""L1 GEMM kernels: blocked matmul + the N:M sparse KAN formulation."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import bspline_lut as bl
from compile.kernels import kan_gemm as kg


@pytest.mark.parametrize(
    "m,k,n", [(8, 8, 8), (128, 128, 128), (300, 257, 130), (1, 64, 10), (33, 5, 3)]
)
def test_matmul_matches_jnp(m, k, n):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    got = kg.matmul(a, b)
    want = a @ b
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (128, 128, 64), (16, 64, 256)])
def test_matmul_block_shapes(bm, bn, bk):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(100, 90)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(90, 70)).astype(np.float32))
    got = kg.matmul(a, b, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), atol=1e-3, rtol=1e-4)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        kg.matmul(jnp.zeros((4, 5)), jnp.zeros((6, 7)))


@pytest.mark.parametrize("g,p,kdim,n,bs", [(5, 3, 7, 4, 33), (3, 3, 22, 10, 64), (10, 3, 12, 6, 1)])
def test_sparse_equals_dense_gemm(g, p, kdim, n, bs):
    """kan_matmul_sparse == dense B @ C — the N:M PE's defining identity."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.uniform(-1, 1, (bs, kdim)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(kdim, g + p, n)).astype(np.float32))
    vals, k = bl.bspline_activations(x, g, p)
    sparse = kg.kan_matmul_sparse(vals, k, c, g, p)
    dense = bl.bspline_dense(x, g, p) @ c.reshape(kdim * (g + p), n)
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense), atol=1e-4, rtol=1e-4)


def test_sparse_batch_padding():
    g, p, kdim, n = 5, 3, 4, 3
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(-1, 1, (200, kdim)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(kdim, g + p, n)).astype(np.float32))
    vals, k = bl.bspline_activations(x, g, p)
    out = kg.kan_matmul_sparse(vals, k, c, g, p, block_rows=128)
    dense = bl.bspline_dense(x, g, p) @ c.reshape(-1, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), atol=1e-4, rtol=1e-4)


@given(
    m=st.integers(1, 80),
    k=st.integers(1, 80),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_matmul_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(kg.matmul(a, b, block_m=32, block_n=32, block_k=32)),
        np.asarray(a @ b),
        atol=2e-3,
        rtol=1e-3,
    )
