"""L1 Pallas tabulation kernel vs the Cox-de Boor oracle.

This is the core correctness signal for the B-spline unit: the kernel's
align -> compare -> LUT pipeline must agree with the recursion up to the
LUT's address-quantization resolution (1/255 in x_a, which bounds the
value error by the spline's Lipschitz constant / 255).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import bspline_lut as bl
from compile.kernels import ref

# max |B'| <= 1 for all P>=1, so address resolution 1/255 with rounding to
# the nearest sample bounds the value error by ~0.5/255 * G (the cardinal
# coordinate stretches x by G/(hi-lo)); keep a conservative tolerance.
TOL = 5e-3


@pytest.mark.parametrize("g,p", [(5, 3), (3, 3), (10, 3), (4, 1), (6, 2), (1, 3), (2, 1)])
@pytest.mark.parametrize("use_onehot", [True, False])
def test_kernel_matches_oracle(g, p, use_onehot):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-1.4, 1.4, (48, 5)).astype(np.float32))
    vals, k = bl.bspline_activations(x, g, p, use_onehot=use_onehot)
    rvals, rk = ref.nonzero_bases(x, g, p)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), atol=TOL)


@pytest.mark.parametrize("g,p", [(5, 3), (4, 2)])
def test_dense_matches_oracle(g, p):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(-1, 1, (33, 7)).astype(np.float32))
    dense = bl.bspline_dense(x, g, p)
    full = ref.cox_de_boor(jnp.clip(x, -1, 1), ref.make_grid(g, p), p)
    np.testing.assert_allclose(
        np.asarray(dense), np.asarray(full).reshape(33, -1), atol=TOL
    )


@pytest.mark.parametrize("bs", [1, 7, 128, 300])
def test_batch_tiling(bs):
    """Non-divisible batch sizes must not change results (block padding)."""
    g, p = 5, 3
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.uniform(-1, 1, (bs, 3)).astype(np.float32))
    vals, k = bl.bspline_activations(x, g, p, block_rows=64)
    rvals, rk = ref.nonzero_bases(x, g, p)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), atol=TOL)


def test_partition_of_unity_through_lut():
    """Sum of the P+1 LUT values == 1 (the kernel's own sanity invariant)."""
    g, p = 7, 3
    x = jnp.asarray(np.linspace(-1, 1, 101, dtype=np.float32)[:, None])
    vals, _ = bl.bspline_activations(x, g, p)
    np.testing.assert_allclose(np.asarray(vals.sum(-1)), 1.0, atol=2 * TOL)


def test_out_of_domain_clamped():
    """Inputs beyond [lo, hi] behave exactly like the clamped boundary."""
    g, p = 5, 3
    far = jnp.asarray([[-9.0, 9.0]], dtype=jnp.float32)
    edge = jnp.asarray([[-1.0, 1.0]], dtype=jnp.float32)
    v1, k1 = bl.bspline_activations(far, g, p)
    v2, k2 = bl.bspline_activations(edge, g, p)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-6)


def test_rejects_bad_inputs():
    x = jnp.zeros((4, 4), dtype=jnp.float32)
    with pytest.raises(ValueError):
        bl.bspline_activations(jnp.zeros((4,)), 5, 3)
    with pytest.raises(ValueError):
        bl.bspline_activations(x, 5, 0)
    with pytest.raises(ValueError):
        bl.bspline_activations(x, 5, 3, lut=jnp.zeros((16, 4)))


def test_quantized_lut_scale():
    lut, scale = bl.build_lut_quantized(3)
    assert lut.dtype == jnp.uint8
    assert int(lut.max()) == 255  # full-range quantization
    full = bl.build_lut(3)
    np.testing.assert_allclose(
        np.asarray(lut, dtype=np.float32) * scale, np.asarray(full), atol=scale
    )


def test_half_table_packed_scheme():
    """The paper's Fig. 5 storage: half of B_{0,3} with two packed values
    per row and bitwise-inverted addressing reconstructs the full table."""
    p = 3
    full = np.asarray(bl.build_lut(p))  # (256, 4): col j = B(x_a + j)
    # packed rows: (B(x_a), B(x_a + 1)) only — half the support [0, 2]
    packed = full[:, :2]
    recon = np.empty_like(full)
    for a in range(256):
        v = packed[a]
        w = packed[255 - a]  # ~addr: x_a -> 1 - x_a
        # j=2: B(x_a+2) = B(2-x_a) = packed[~a][1];  j=3: B(x_a+3) = B(1-x_a)
        recon[a] = [v[0], v[1], w[1], w[0]]
    np.testing.assert_allclose(recon, full, atol=1e-6)


@given(
    g=st.integers(1, 12),
    p=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    bs=st.integers(1, 40),
    feats=st.integers(1, 8),
)
@settings(max_examples=25, deadline=None)
def test_kernel_hypothesis_sweep(g, p, seed, bs, feats):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-2, 2, (bs, feats)).astype(np.float32))
    vals, k = bl.bspline_activations(x, g, p)
    rvals, rk = ref.nonzero_bases(x, g, p)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), atol=TOL)
