"""AOT export: container format round-trip + HLO text structure."""

import json
import struct

import jax
import numpy as np
import pytest

from compile import aot, model, quantize, train


def read_container(path):
    """Reference reader for the KANQ/KGLD/KWTS container (mirrors rust)."""
    raw = path.read_bytes()
    magic, hlen = raw[:8], struct.unpack("<I", raw[8:12])[0]
    header = json.loads(raw[12 : 12 + hlen].decode("utf-8"))
    body = raw[12 + hlen :]
    tensors = {}
    for name, t in header["tensors"].items():
        a = np.frombuffer(
            body[t["offset"] : t["offset"] + t["nbytes"]], dtype=np.dtype(t["dtype"])
        ).reshape(t["shape"])
        tensors[name] = a
    return magic, header, tensors


@pytest.fixture(scope="module")
def tiny_quantized(tmp_path_factory):
    spec = model.quickstart_kan()
    xtr, ytr, xte, yte = train.blob_datasets()
    params, _ = train.train_model(
        spec, xtr, ytr, xte, yte, steps=30, batch_size=64, log_every=30
    )
    return spec, params, quantize.QuantizedModel(params, spec), (xte, yte)


def test_kanq_roundtrip(tiny_quantized, tmp_path):
    spec, params, qm, _ = tiny_quantized
    path = tmp_path / "m.kanq"
    aot.export_kanq(qm, path)
    magic, header, tensors = read_container(path)
    assert magic == aot.MAGIC_KANQ
    assert header["dims"] == list(spec.dims)
    assert header["shift"] == quantize.SHIFT
    for i, layer in enumerate(qm.layers):
        np.testing.assert_array_equal(tensors[f"l{i}.lut"], layer.lut)
        np.testing.assert_array_equal(tensors[f"l{i}.coeff"], layer.coeff_q)
        np.testing.assert_array_equal(tensors[f"l{i}.base"], layer.base_q)
        assert header["layers"][i]["m1"] == layer.m1


def test_golden_replay(tiny_quantized, tmp_path):
    """The exported goldens must replay exactly through the python engine
    (the same check rust runs against its engine)."""
    spec, params, qm, (xte, yte) = tiny_quantized
    path = tmp_path / "m.kgld"
    aot.export_golden(qm, xte[:16], yte[:16], path)
    magic, header, tensors = read_container(path)
    assert magic == aot.MAGIC_GOLD
    x_q = tensors["x_q"]
    t = qm.forward_from_q(x_q)
    np.testing.assert_array_equal(t, tensors["t_final"])
    np.testing.assert_array_equal(np.argmax(t, -1).astype(np.int32), tensors["pred"])
    l0 = qm.layers[0]
    vals, k = quantize.bspline_unit_q(x_q, l0.lut, l0.spec.grid, l0.spec.degree)
    np.testing.assert_array_equal(vals, tensors["l0.vals"])
    np.testing.assert_array_equal(k, tensors["l0.k"])


def test_hlo_export_structure(tiny_quantized, tmp_path):
    spec, params, qm, _ = tiny_quantized
    written = aot.export_hlo(params, spec, (1,), tmp_path)
    assert written == [f"{spec.name}_b1.hlo.txt"]
    text = (tmp_path / written[0]).read_text()
    assert text.startswith("HloModule")
    # weights container records the parameter order
    magic, header, tensors = read_container(tmp_path / f"{spec.name}.kwts")
    assert magic == aot.MAGIC_WTS
    # entry layout must have len(order) + 1 parameters (input last)
    n_params = len(header["order"]) + 1
    entry = text.split("entry_computation_layout=")[1].split("\n")[0]
    assert entry.count("f32[") == n_params + 1  # + the tupled result


def test_hlo_numerics_vs_jax(tiny_quantized, tmp_path):
    """Execute the exported StableHLO via jax and compare with the direct
    forward — proves the interchange module computes the same function
    (the rust side re-checks this through PJRT)."""
    spec, params, qm, (xte, _) = tiny_quantized
    aot.export_hlo(params, spec, (4,), tmp_path)
    _, header, tensors = read_container(tmp_path / f"{spec.name}.kwts")
    x = np.asarray(xte[:4], np.float32)
    import jax.numpy as jnp

    want = model.kan_forward(params, jnp.asarray(x), spec, use_pallas=False)
    args = [jnp.asarray(tensors[n]) for n in header["order"]] + [jnp.asarray(x)]

    # round-trip the same fwd through jit (the HLO text itself is executed
    # in the rust integration tests; here we validate the function + order)
    def fwd(*a):
        *wts, xx = a
        ps = [
            {"coeff": wts[3 * i], "base": wts[3 * i + 1]}
            for i in range(len(spec.layers))
        ]
        luts = [wts[3 * i + 2] for i in range(len(spec.layers))]
        return model.kan_forward(ps, xx, spec, use_pallas=True, luts=luts)

    got = jax.jit(fwd)(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.05, rtol=0.01)


def test_container_writer_rejects_bad_magic(tmp_path):
    with pytest.raises(AssertionError):
        aot.write_container(tmp_path / "x.bin", b"BAD", {}, {})
