"""L2 model: layer equivalence (pallas vs oracle), shapes, training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train


@pytest.mark.parametrize("g,p,kdim,n", [(5, 3, 6, 4), (3, 3, 22, 10), (10, 3, 8, 5)])
def test_layer_pallas_matches_oracle(g, p, kdim, n):
    spec = model.KanLayerSpec(kdim, n, g, p)
    params = model.init_layer(jax.random.PRNGKey(0), spec)
    x = jnp.asarray(np.random.default_rng(0).uniform(-1, 1, (17, kdim)).astype(np.float32))
    got = model.kan_layer(params, x, spec, use_pallas=True)
    want = model.kan_layer(params, x, spec, use_pallas=False)
    # pallas path quantizes the LUT address (1/255); coefficients amplify it
    amax = float(jnp.abs(params["coeff"]).sum(axis=(0, 1)).max())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=6e-3 * max(amax, 1.0))


def test_forward_shapes():
    spec = model.quickstart_kan()
    params = model.init_model(jax.random.PRNGKey(1), spec)
    x = jnp.zeros((9, spec.dims[0]))
    out = model.kan_forward(params, x, spec, use_pallas=False)
    assert out.shape == (9, spec.dims[-1])


def test_init_shapes():
    spec = model.KanLayerSpec(7, 5, 4, 2)
    params = model.init_layer(jax.random.PRNGKey(0), spec)
    assert params["coeff"].shape == (7, 6, 5)
    assert params["base"].shape == (7, 5)
    assert spec.num_bases == 6


def test_model_spec_layers():
    spec = model.KanModelSpec(dims=(4, 8, 3), grid=5, degree=3)
    layers = spec.layers
    assert [(l.in_dim, l.out_dim) for l in layers] == [(4, 8), (8, 3)]
    assert all(l.grid == 5 and l.degree == 3 for l in layers)


def test_training_reduces_loss():
    spec = model.quickstart_kan()
    xtr, ytr, xte, yte = train.blob_datasets()
    params, metrics = train.train_model(
        spec, xtr, ytr, xte, yte, steps=60, batch_size=64, log_every=30
    )
    hist = metrics["history"]
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert metrics["fp32_test_acc"] > 0.5  # well above 1/3 chance


def test_adam_step_moves_params():
    spec = model.quickstart_kan()
    params = model.init_model(jax.random.PRNGKey(2), spec)
    opt = model.adam_init(params)
    g = jax.tree.map(jnp.ones_like, params)
    new_params, opt2 = model.adam_update(g, opt, params, lr=1e-2)
    diff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    assert max(jax.tree.leaves(diff)) > 0
    assert int(opt2.step) == 1


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 1.0, 0.0]])
    labels = jnp.asarray([0, 1])
    got = float(model.cross_entropy(logits, labels))
    probs = jax.nn.softmax(logits)
    want = float(-jnp.mean(jnp.log(probs[jnp.arange(2), labels])))
    assert abs(got - want) < 1e-6


def test_params_save_load_roundtrip(tmp_path):
    spec = model.quickstart_kan()
    params = model.init_model(jax.random.PRNGKey(3), spec)
    path = tmp_path / "p.npz"
    train.save_params(params, path)
    loaded = train.load_params(path)
    for a, b in zip(params, loaded):
        np.testing.assert_array_equal(np.asarray(a["coeff"]), np.asarray(b["coeff"]))
        np.testing.assert_array_equal(np.asarray(a["base"]), np.asarray(b["base"]))
