"""Integer-only quantized pipeline: the bit-exact spec the rust engine mirrors."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model, quantize, train
from compile.kernels import ref


def test_activation_quant_roundtrip():
    x = np.linspace(-1, 127 / 128, 256, dtype=np.float32)
    xq = quantize.quantize_activations(x)
    xd = quantize.dequantize_activations(xq)
    assert np.abs(xd - x).max() <= 0.5 / 128 + 1e-6
    assert xq.dtype == np.uint8


def test_activation_quant_zero_point():
    assert quantize.quantize_activations(np.float32(0.0)) == quantize.ZP
    assert quantize.quantize_activations(np.float32(-1.0)) == 0
    assert quantize.quantize_activations(np.float32(1.0)) == 255


def test_symmetric_quant_roundtrip():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(20, 30)).astype(np.float32)
    q, s = quantize.quantize_symmetric(w)
    assert q.dtype == np.int8
    assert np.abs(q.astype(np.float32) * s - w).max() <= s / 2 + 1e-7


def test_symmetric_quant_zero_tensor():
    q, s = quantize.quantize_symmetric(np.zeros((3, 3), np.float32))
    assert (q == 0).all() and s == 1.0


@pytest.mark.parametrize("p", [1, 2, 3])
def test_lut_q_matches_cardinal(p):
    lut, s_b = quantize.build_lut_q(p)
    assert lut.shape == (256, p + 1)
    a = np.arange(256) / 256.0
    for j in range(p + 1):
        want = np.asarray(ref.cardinal_bspline(jnp.asarray(a + (p - j), dtype=jnp.float32), p))
        got = lut[:, j].astype(np.float64) * s_b
        assert np.abs(got - want).max() <= s_b / 2 + 1e-6


@pytest.mark.parametrize("g,p", [(5, 3), (3, 3), (10, 3), (4, 1), (6, 2)])
def test_bspline_unit_q_vs_oracle(g, p):
    """Integer unit (Compare/Align/LUT) matches the float oracle at the
    dequantized input points, within LUT resolution."""
    lut, s_b = quantize.build_lut_q(p)
    rng = np.random.default_rng(1)
    x = rng.uniform(-1, 127 / 128, (64, 3)).astype(np.float32)
    xq = quantize.quantize_activations(x)
    vals, k = quantize.bspline_unit_q(xq, lut, g, p)
    xd = jnp.asarray(quantize.dequantize_activations(xq))
    rvals, rk = ref.nonzero_bases(xd, g, p)
    np.testing.assert_array_equal(k, np.asarray(rk))
    # value error <= address resolution (g/256 in x_a) + LUT quantization
    tol = s_b + (g / 256.0) * 1.1
    assert np.abs(vals.astype(np.float64) * s_b - np.asarray(rvals)).max() <= tol


def test_bspline_unit_q_partition_of_unity():
    g, p = 5, 3
    lut, s_b = quantize.build_lut_q(p)
    xq = np.arange(256, dtype=np.uint8)[:, None]
    vals, _ = quantize.bspline_unit_q(xq, lut, g, p)
    sums = vals.astype(np.float64).sum(-1) * s_b
    np.testing.assert_allclose(sums, 1.0, atol=0.02)


def test_bspline_unit_q_edges():
    g, p = 5, 3
    lut, _ = quantize.build_lut_q(p)
    vals, k = quantize.bspline_unit_q(np.asarray([[0], [255]], np.uint8), lut, g, p)
    assert k[0, 0] == p  # first interval
    assert k[1, 0] == g + p - 1  # last interval


def test_quantized_model_accuracy_close_to_fp32():
    spec = model.quickstart_kan()
    xtr, ytr, xte, yte = train.blob_datasets()
    params, metrics = train.train_model(
        spec, xtr, ytr, xte, yte, steps=150, batch_size=64, log_every=100
    )
    qm = quantize.QuantizedModel(params, spec)
    drop = metrics["fp32_test_acc"] - qm.accuracy(xte, yte)
    assert abs(drop) < 0.03, f"quantization drop {drop}"  # paper: < 1%


def test_requantize_rounding():
    layer = _tiny_layer()
    t = np.asarray([0, 1 << quantize.SHIFT, -(1 << quantize.SHIFT)], dtype=np.int64)
    yq = layer.requantize(t)
    np.testing.assert_array_equal(yq, [128, 129, 127])


def test_requantize_saturates():
    layer = _tiny_layer()
    big = np.asarray([1 << 62, -(1 << 62)], dtype=np.int64)
    yq = layer.requantize(big)
    np.testing.assert_array_equal(yq, [255, 0])


def _tiny_layer():
    spec = model.KanLayerSpec(2, 2, 3, 3)
    params = {
        "coeff": np.ones(spec.coeff_shape, np.float32) * 0.1,
        "base": np.ones((2, 2), np.float32) * 0.1,
    }
    return quantize.QuantizedLayer(params, spec)


@given(seed=st.integers(0, 2**31 - 1), g=st.integers(1, 12), p=st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_unit_q_hypothesis(seed, g, p):
    """k always lands in [P, G+P-1]; addresses stay in range; vals bounded."""
    lut, _ = quantize.build_lut_q(p)
    rng = np.random.default_rng(seed)
    xq = rng.integers(0, 256, (16, 4)).astype(np.uint8)
    vals, k = quantize.bspline_unit_q(xq, lut, g, p)
    assert k.min() >= p and k.max() <= g + p - 1
    assert vals.dtype == np.uint8
