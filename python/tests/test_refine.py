"""Grid refinement (paper Sec. II-B): finer uniform grids reproduce the
learned activations without retraining."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, refine, train


def _layer(seed=0, g=3, p=3, k=4, n=3):
    spec = model.KanLayerSpec(k, n, g, p)
    params = model.init_layer(jax.random.PRNGKey(seed), spec)
    return params, spec


@pytest.mark.parametrize("new_g", [6, 9, 12, 24])
def test_refinement_preserves_activations(new_g):
    """A degree-P spline space on grid G embeds in the space on grid cG
    (uniform knots are nested under integer subdivision), so refinement
    must be near-exact."""
    params, spec = _layer(g=3)
    new_params, new_spec = refine.refine_layer(params, spec, new_g)
    err = refine.refinement_error(params, spec, new_params, new_spec)
    assert err < 1e-4, f"G=3 -> G={new_g}: err {err}"


def test_non_nested_refinement_small_error():
    # G=3 -> G=5 is not nested; the lstsq fit is approximate but close
    params, spec = _layer(g=3)
    new_params, new_spec = refine.refine_layer(params, spec, 5)
    err = refine.refinement_error(params, spec, new_params, new_spec)
    scale = float(jnp.abs(params["coeff"]).max())
    assert err < 0.12 * max(scale, 1e-6), f"err {err} vs coeff scale {scale}"


def test_coarsening_rejected():
    params, spec = _layer(g=5)
    with pytest.raises(ValueError):
        refine.refine_layer(params, spec, 3)


def test_refined_model_keeps_accuracy():
    """End-to-end: refine the trained quickstart model to a finer grid and
    check classification accuracy is preserved (the paper's argument for
    the uniform-grid-only hardware assumption)."""
    spec = model.quickstart_kan()  # G=5
    xtr, ytr, xte, yte = train.blob_datasets()
    params, metrics = train.train_model(
        spec, xtr, ytr, xte, yte, steps=150, batch_size=64, log_every=150
    )
    new_params, new_spec = refine.refine_model(params, spec, 10)
    logits = model.kan_forward(new_params, jnp.asarray(xte), new_spec, use_pallas=False)
    acc = float(model.accuracy(logits, jnp.asarray(yte)))
    assert acc >= metrics["fp32_test_acc"] - 0.02, (
        f"refined acc {acc} vs original {metrics['fp32_test_acc']}"
    )


def test_base_weights_untouched():
    params, spec = _layer()
    new_params, _ = refine.refine_layer(params, spec, 6)
    np.testing.assert_array_equal(
        np.asarray(params["base"]), np.asarray(new_params["base"])
    )
