"""Properties of the Cox-de Boor oracle (the root of the correctness chain)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref

GRIDS = [(1, 0), (3, 1), (5, 2), (5, 3), (3, 3), (10, 3), (2, 3), (4, 1)]


@pytest.mark.parametrize("g,p", GRIDS)
def test_partition_of_unity(g, p):
    """B-splines sum to 1 everywhere inside the input domain."""
    knots = ref.make_grid(g, p)
    x = jnp.linspace(-1.0, 1.0, 257)
    b = ref.cox_de_boor(x, knots, p)
    np.testing.assert_allclose(np.asarray(b.sum(-1)), 1.0, atol=1e-5)


@pytest.mark.parametrize("g,p", GRIDS)
def test_local_support(g, p):
    """At most P+1 bases are non-zero at any point (paper Sec. IV-A)."""
    knots = ref.make_grid(g, p)
    x = jnp.linspace(-1.0, 1.0, 511)
    b = ref.cox_de_boor(x, knots, p)
    assert int((np.asarray(b) > 1e-12).sum(-1).max()) <= p + 1


@pytest.mark.parametrize("g,p", GRIDS)
def test_nonnegative(g, p):
    knots = ref.make_grid(g, p)
    x = jnp.linspace(-1.0, 1.0, 257)
    b = ref.cox_de_boor(x, knots, p)
    assert float(b.min()) >= -1e-7


@pytest.mark.parametrize("g,p", GRIDS)
def test_shape(g, p):
    knots = ref.make_grid(g, p)
    x = jnp.zeros((4, 6))
    assert ref.cox_de_boor(x, knots, p).shape == (4, 6, g + p)
    assert ref.num_bases(g, p) == g + p


@pytest.mark.parametrize("p", [1, 2, 3, 4])
def test_cardinal_symmetry(p):
    """B_{0,P} is symmetric about (P+1)/2 (enables half-table storage)."""
    u = jnp.linspace(0.0, p + 1.0, 401)
    a = ref.cardinal_bspline(u, p)
    b = ref.cardinal_bspline(p + 1.0 - u, p)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("p", [1, 2, 3])
def test_cardinal_peak_at_midpoint(p):
    mid = (p + 1) / 2.0
    peak = float(ref.cardinal_bspline(jnp.float32(mid), p))
    u = jnp.linspace(0.0, p + 1.0, 401)
    assert peak >= float(ref.cardinal_bspline(u, p).max()) - 1e-6


@pytest.mark.parametrize("g,p", [(5, 3), (3, 2), (10, 3), (4, 1)])
def test_translation_invariance(g, p):
    """Eq. 4: B_{t_k,P}(x) == B_{0,P}((x - t_0)/dx - k)."""
    knots = ref.make_grid(g, p)
    x = jnp.linspace(-1.0, 0.999, 101)
    dense = ref.cox_de_boor(x, knots, p)
    dx = 2.0 / g
    u = (x + 1.0) / dx + p  # (x - t_0)/dx
    for i in range(g + p):
        card = ref.cardinal_bspline(u - i, p)
        np.testing.assert_allclose(np.asarray(card), np.asarray(dense[:, i]), atol=3e-5)


@pytest.mark.parametrize("g,p", [(5, 3), (3, 1), (7, 2)])
def test_sparse_dense_roundtrip(g, p):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-1.3, 1.3, (32, 5)).astype(np.float32))
    vals, k = ref.nonzero_bases(x, g, p)
    dense = ref.dense_from_sparse(vals, k, g, p)
    full = ref.cox_de_boor(jnp.clip(x, -1, 1), ref.make_grid(g, p), p)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(full), atol=1e-6)


@pytest.mark.parametrize("g,p", [(5, 3), (3, 1)])
def test_interval_index_bounds(g, p):
    x = jnp.asarray(np.random.default_rng(1).uniform(-5, 5, 200).astype(np.float32))
    k = np.asarray(ref.interval_index(x, g, p))
    assert k.min() >= p and k.max() <= g + p - 1


@given(
    g=st.integers(1, 12),
    p=st.integers(0, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_partition_of_unity_hypothesis(g, p, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1, 1, 64).astype(np.float32))
    b = ref.cox_de_boor(x, ref.make_grid(g, p), p)
    np.testing.assert_allclose(np.asarray(b.sum(-1)), 1.0, atol=1e-4)


def test_make_grid_validation():
    with pytest.raises(ValueError):
        ref.make_grid(0, 3)
    with pytest.raises(ValueError):
        ref.make_grid(5, -1)
    with pytest.raises(ValueError):
        ref.make_grid(5, 3, 1.0, -1.0)
