"""Build-time training of the benchmark KAN models (L2).

Trains through the Cox-de Boor oracle path (differentiable); the tabulated
LUT path is inference-only, mirroring the paper's inference accelerator.
Run as ``python -m compile.train`` (from ``python/``) or via ``aot.py``.
"""

from __future__ import annotations

import functools
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model


def _batches(rng: np.random.Generator, n: int, bs: int):
    while True:
        idx = rng.permutation(n)
        for i in range(0, n - bs + 1, bs):
            yield idx[i : i + bs]


def train_model(
    spec: model.KanModelSpec,
    x_train: np.ndarray,
    y_train: np.ndarray,
    x_test: np.ndarray,
    y_test: np.ndarray,
    *,
    steps: int = 400,
    batch_size: int = 128,
    lr: float = 2e-3,
    weight_decay: float = 1e-5,
    seed: int = 0,
    log_every: int = 50,
    input_scale: float = 1.0,
) -> tuple[list[dict[str, jax.Array]], dict]:
    """Train ``spec`` with Adam + cross-entropy; returns (params, metrics).

    ``input_scale`` maps raw inputs into the first layer's spline domain
    (synth-digits pixels live in [0,1]; we stretch to [-1,1] upstream, so
    the default is identity here).
    """
    params = model.init_model(jax.random.PRNGKey(seed), spec)
    opt = model.adam_init(params)

    @jax.jit
    def loss_fn(params, xb, yb):
        logits = model.kan_forward(params, xb * input_scale, spec, use_pallas=False)
        return model.cross_entropy(logits, yb)

    @jax.jit
    def step_fn(params, opt, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        params, opt = model.adam_update(grads, opt, params, lr=lr, weight_decay=weight_decay)
        return params, opt, loss

    @jax.jit
    def eval_fn(params, xb, yb):
        logits = model.kan_forward(params, xb * input_scale, spec, use_pallas=False)
        return model.accuracy(logits, yb)

    rng = np.random.default_rng(seed)
    it = _batches(rng, len(x_train), batch_size)
    history = []
    t0 = time.time()
    for s in range(steps):
        idx = next(it)
        xb = jnp.asarray(x_train[idx])
        yb = jnp.asarray(y_train[idx])
        params, opt, loss = step_fn(params, opt, xb, yb)
        if (s + 1) % log_every == 0 or s == 0:
            acc = float(eval_fn(params, jnp.asarray(x_test), jnp.asarray(y_test)))
            history.append({"step": s + 1, "loss": float(loss), "test_acc": acc})
            print(f"[{spec.name}] step {s+1:5d}  loss {float(loss):.4f}  test_acc {acc:.4f}")
    final_acc = float(eval_fn(params, jnp.asarray(x_test), jnp.asarray(y_test)))
    metrics = {
        "name": spec.name,
        "dims": list(spec.dims),
        "grid": spec.grid,
        "degree": spec.degree,
        "steps": steps,
        "fp32_test_acc": final_acc,
        "train_seconds": time.time() - t0,
        "history": history,
    }
    return params, metrics


@functools.lru_cache(maxsize=None)
def digit_datasets(n_train: int = 6000, n_test: int = 1000):
    """Seeded synth-digits splits, pixels remapped to the spline domain [-1,1]."""
    xtr, ytr = data.synth_digits(n_train, seed=1)
    xte, yte = data.synth_digits(n_test, seed=2)
    return 2.0 * xtr - 1.0, ytr, 2.0 * xte - 1.0, yte


@functools.lru_cache(maxsize=None)
def blob_datasets(n_train: int = 2000, n_test: int = 500):
    xtr, ytr = data.synth_blobs(n_train, seed=3)
    xte, yte = data.synth_blobs(n_test, seed=4)
    return xtr, ytr, xte, yte


def train_mnist_kan(steps: int = 500) -> tuple[list[dict], dict]:
    xtr, ytr, xte, yte = digit_datasets()
    return train_model(model.mnist_kan(), xtr, ytr, xte, yte, steps=steps)


def train_quickstart(steps: int = 300) -> tuple[list[dict], dict]:
    xtr, ytr, xte, yte = blob_datasets()
    return train_model(model.quickstart_kan(), xtr, ytr, xte, yte, steps=steps, batch_size=64)


def save_params(params: list[dict[str, jax.Array]], path: Path) -> None:
    flat = {}
    for i, layer in enumerate(params):
        flat[f"l{i}_coeff"] = np.asarray(layer["coeff"])
        flat[f"l{i}_base"] = np.asarray(layer["base"])
    np.savez(path, **flat)


def load_params(path: Path) -> list[dict[str, jnp.ndarray]]:
    z = np.load(path)
    n_layers = sum(1 for k in z.files if k.endswith("_coeff"))
    return [
        {"coeff": jnp.asarray(z[f"l{i}_coeff"]), "base": jnp.asarray(z[f"l{i}_base"])}
        for i in range(n_layers)
    ]


def main() -> None:
    out = Path(__file__).resolve().parents[2] / "artifacts"
    out.mkdir(exist_ok=True)
    all_metrics = {}
    for name, fn in [("quickstart_kan", train_quickstart), ("mnist_kan", train_mnist_kan)]:
        params, metrics = fn()
        save_params(params, out / f"{name}_params.npz")
        all_metrics[name] = metrics
    (out / "train_metrics.json").write_text(json.dumps(all_metrics, indent=2))
    print(json.dumps({k: v["fp32_test_acc"] for k, v in all_metrics.items()}, indent=2))


if __name__ == "__main__":
    main()


@functools.lru_cache(maxsize=None)
def timeseries_datasets(n_train: int = 4000, n_test: int = 800):
    xtr, ytr = data.synth_timeseries_features(n_train, seed=5)
    xte, yte = data.synth_timeseries_features(n_test, seed=6)
    return xtr, ytr, xte, yte


def train_catch22(steps: int = 400) -> tuple[list[dict], dict]:
    xtr, ytr, xte, yte = timeseries_datasets()
    return train_model(
        model.catch22_kan(10), xtr, ytr, xte, yte, steps=steps, batch_size=128, lr=5e-3
    )
