"""Grid refinement without retraining (paper Sec. II-B / [1]).

KAN-SAs assumes uniform grids; the paper argues this does not limit
generality because a spline on any grid can be re-fit on a *finer uniform
grid* by least squares on the coefficients — "it is possible to fine-grain
the grid without retraining, using least squares to compute the new
coefficients". This module implements that operation and is exercised by
`python/tests/test_refine.py` and the LUT-size ablation.

Given a trained layer with coefficients `c` on grid G_old, we sample the
learned activations at dense points, evaluate the new basis (grid G_new)
at the same points, and solve `B_new @ c_new ~= phi(x)` per (input,
output) pair — vectorized as a single lstsq with multiple right-hand
sides.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernels import ref
from . import model


def refine_layer(
    params: dict[str, jnp.ndarray],
    spec: model.KanLayerSpec,
    new_grid: int,
    samples: int = 512,
) -> tuple[dict[str, jnp.ndarray], model.KanLayerSpec]:
    """Re-fit one layer's spline coefficients on a finer uniform grid.

    Returns (new_params, new_spec). The base-path weights are unchanged
    (the ReLU term does not depend on the grid).
    """
    if new_grid < spec.grid:
        raise ValueError(f"refinement must not coarsen: {spec.grid} -> {new_grid}")
    xs = jnp.linspace(spec.lo, spec.hi, samples)
    b_old = ref.cox_de_boor(xs, ref.make_grid(spec.grid, spec.degree, spec.lo, spec.hi), spec.degree)
    b_new = ref.cox_de_boor(xs, ref.make_grid(new_grid, spec.degree, spec.lo, spec.hi), spec.degree)

    coeff = np.asarray(params["coeff"])  # (K, M_old, N)
    k_dim, m_old, n_out = coeff.shape
    # activations of every learned phi at the sample points:
    # (samples, M_old) @ (K, M_old, N) -> (K, samples, N)
    targets = np.einsum("sm,kmn->ksn", np.asarray(b_old), coeff)
    # one lstsq, shared design matrix: (samples, M_new) x (K*N rhs)
    rhs = targets.transpose(1, 0, 2).reshape(samples, k_dim * n_out)
    sol, *_ = np.linalg.lstsq(np.asarray(b_new), rhs, rcond=None)
    new_coeff = sol.reshape(new_grid + spec.degree, k_dim, n_out).transpose(1, 0, 2)

    new_spec = spec._replace(grid=new_grid)
    return (
        {"coeff": jnp.asarray(new_coeff, jnp.float32), "base": params["base"]},
        new_spec,
    )


def refine_model(
    params: list[dict[str, jnp.ndarray]],
    spec: model.KanModelSpec,
    new_grid: int,
) -> tuple[list[dict[str, jnp.ndarray]], model.KanModelSpec]:
    """Refine every layer of a model to `new_grid`."""
    out = []
    for p, layer in zip(params, spec.layers):
        np_, _ = refine_layer(p, layer, new_grid)
        out.append(np_)
    return out, spec._replace(grid=new_grid)


def refinement_error(
    params: dict[str, jnp.ndarray],
    spec: model.KanLayerSpec,
    new_params: dict[str, jnp.ndarray],
    new_spec: model.KanLayerSpec,
    samples: int = 1024,
) -> float:
    """Max |phi_old(x) - phi_new(x)| over the domain, across all splines."""
    xs = jnp.linspace(spec.lo, spec.hi, samples)
    b_old = ref.cox_de_boor(xs, ref.make_grid(spec.grid, spec.degree, spec.lo, spec.hi), spec.degree)
    b_new = ref.cox_de_boor(xs, ref.make_grid(new_spec.grid, new_spec.degree, spec.lo, spec.hi), new_spec.degree)
    old = np.einsum("sm,kmn->ksn", np.asarray(b_old), np.asarray(params["coeff"]))
    new = np.einsum("sm,kmn->ksn", np.asarray(b_new), np.asarray(new_params["coeff"]))
    return float(np.abs(old - new).max())
