"""Synthetic datasets (build-time only).

The image has no network access, so MNIST cannot be downloaded. Per the
substitution policy in DESIGN.md, MNIST is replaced by **synth-digits**:
procedurally rendered 28x28 grayscale digits built from seven-segment
style strokes with random affine jitter and noise. The evaluation only
needs (a) a learnable non-trivial 10-class task of the same tensor shape
so MNIST-KAN trains to a high-90s accuracy, and (b) the trained network's
B-spline activation statistics for the quantization-accuracy experiment —
both of which synth-digits provides. Everything is seeded and
deterministic.
"""

from __future__ import annotations

import numpy as np

# Seven-segment layout on a unit square: (x0, y0, x1, y1) per segment.
#     _a_
#    f| g |b
#     |_ _|
#    e|   |c
#     |_d_|
_SEGS = {
    "a": (0.2, 0.1, 0.8, 0.1),
    "b": (0.8, 0.1, 0.8, 0.5),
    "c": (0.8, 0.5, 0.8, 0.9),
    "d": (0.2, 0.9, 0.8, 0.9),
    "e": (0.2, 0.5, 0.2, 0.9),
    "f": (0.2, 0.1, 0.2, 0.5),
    "g": (0.2, 0.5, 0.8, 0.5),
}

_DIGIT_SEGS = {
    0: "abcdef",
    1: "bc",
    2: "abged",
    3: "abgcd",
    4: "fgbc",
    5: "afgcd",
    6: "afgedc",
    7: "abc",
    8: "abcdefg",
    9: "abcdfg",
}


def _render(digit: int, rng: np.random.Generator, size: int = 28) -> np.ndarray:
    """Rasterize one jittered digit to a (size, size) float image in [0,1]."""
    img = np.zeros((size, size), dtype=np.float32)
    ang = rng.uniform(-0.25, 0.25)
    scale = rng.uniform(0.75, 1.05)
    dx, dy = rng.uniform(-0.08, 0.08, size=2)
    ca, sa = np.cos(ang), np.sin(ang)
    thick = rng.uniform(0.9, 1.6)
    for s in _DIGIT_SEGS[digit]:
        x0, y0, x1, y1 = _SEGS[s]
        # sample points along the segment, map through the jitter transform
        t = np.linspace(0.0, 1.0, 24)
        xs = x0 + (x1 - x0) * t - 0.5
        ys = y0 + (y1 - y0) * t - 0.5
        xr = (ca * xs - sa * ys) * scale + 0.5 + dx
        yr = (sa * xs + ca * ys) * scale + 0.5 + dy
        px = np.clip(xr * (size - 1), 0, size - 1)
        py = np.clip(yr * (size - 1), 0, size - 1)
        for cx, cy in zip(px, py):
            ix, iy = int(cx), int(cy)
            for ox in (0, 1):
                for oy in (0, 1):
                    x, y = ix + ox, iy + oy
                    if x < size and y < size:
                        w = max(0.0, 1.0 - abs(cx - x) / thick) * max(
                            0.0, 1.0 - abs(cy - y) / thick
                        )
                        img[y, x] = max(img[y, x], w)
    img += rng.normal(0.0, 0.06, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def synth_digits(
    n: int, seed: int = 0, size: int = 28
) -> tuple[np.ndarray, np.ndarray]:
    """n jittered digit images -> (images (n, size*size) in [0,1], labels)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    imgs = np.stack([_render(int(d), rng, size) for d in labels])
    return imgs.reshape(n, size * size), labels


def synth_blobs(
    n: int, dim: int = 4, classes: int = 3, seed: int = 0, center_seed: int = 7
) -> tuple[np.ndarray, np.ndarray]:
    """Gaussian-blob classification in [-1,1]^dim for the quickstart model.

    Class centers are drawn from ``center_seed`` (fixed across splits so
    train and test share the same distribution); ``seed`` only drives the
    per-sample draws.
    """
    centers = (
        np.random.default_rng(center_seed)
        .uniform(-0.7, 0.7, size=(classes, dim))
        .astype(np.float32)
    )
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, classes, size=n).astype(np.int32)
    x = centers[labels] + rng.normal(0, 0.25, size=(n, dim)).astype(np.float32)
    return np.clip(x, -1.0, 1.0), labels


def _catch22ish_features(ts: np.ndarray) -> np.ndarray:
    """22 cheap catch22-style summary statistics of one time series.

    Not the canonical catch22 set (pycatch22 is unavailable offline), but
    a comparable mix of moments, autocorrelations, spectral and
    distributional summaries — enough for the Catch22-KAN workload shape
    (a [22, X] single-layer KAN) and a learnable classification task.
    """
    n = len(ts)
    mu, sd = ts.mean(), ts.std() + 1e-9
    z = (ts - mu) / sd
    diff = np.diff(ts)
    acf = [float(np.dot(z[:-k], z[k:]) / (n - k)) for k in (1, 2, 3, 5, 8, 13)]
    spec = np.abs(np.fft.rfft(z)) ** 2
    spec = spec / (spec.sum() + 1e-9)
    feats = np.array(
        [
            mu,
            sd,
            float(((z > 0).sum()) / n),
            float(np.abs(diff).mean()),
            float(diff.std()),
            *acf,
            float(z.max()),
            float(z.min()),
            float(np.median(z)),
            float((z**3).mean()),  # skew
            float((z**4).mean()),  # kurtosis
            float(spec[: len(spec) // 4].sum()),  # low-band power
            float(spec[len(spec) // 4 :].sum()),  # high-band power
            float(-(spec * np.log(spec + 1e-12)).sum()),  # spectral entropy
            float((np.sign(z[:-1]) != np.sign(z[1:])).mean()),  # zero crossings
            float(np.percentile(z, 90) - np.percentile(z, 10)),
            float((diff > 0).mean()),
        ],
        dtype=np.float32,
    )
    assert feats.shape == (22,)
    return feats


def synth_timeseries_features(
    n: int, classes: int = 10, length: int = 128, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """UCR-style synthetic task: each class is a parameterized process
    (sine freq/phase + AR noise + trend); features are catch22-style.
    Features are tanh-squashed into the spline domain [-1, 1]."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, classes, size=n).astype(np.int32)
    t = np.arange(length) / length
    feats = np.empty((n, 22), dtype=np.float32)
    for i, c in enumerate(labels):
        freq = 2.0 + 1.7 * c
        amp = 0.5 + 0.1 * (c % 3)
        trend = 0.3 * ((c % 4) - 1.5)
        ar = 0.3 + 0.05 * (c % 5)
        noise = np.zeros(length)
        eps = rng.normal(0, 0.3, length)
        for k in range(1, length):
            noise[k] = ar * noise[k - 1] + eps[k]
        ts = amp * np.sin(2 * np.pi * freq * t + rng.uniform(0, 2 * np.pi)) + trend * t + noise
        feats[i] = _catch22ish_features(ts)
    return np.tanh(feats * 0.5), labels
