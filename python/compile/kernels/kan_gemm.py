"""L1 Pallas kernel: tiled GEMM for the KAN linear-combination stage.

Once the B-spline unit has produced the activation matrix **B** (dense
``(BS, K*(G+P))`` or sparse ``(vals, k)``), the rest of the KAN layer is a
plain GEMM against the coefficient matrix ``C`` of shape
``(K*(G+P), N)`` (paper Fig. 1c / Sec. II-A). Two kernels live here:

* :func:`matmul` — a classic VMEM-blocked weight-stationary matmul. The
  BlockSpec is the software analogue of the paper's dataflow: the ``C``
  tile stays resident (weight-stationary) while activation tiles stream
  through and partial sums accumulate in a VMEM scratch tile.
* :func:`kan_matmul_sparse` — the N:M-aware formulation the vector PEs
  implement (Sec. IV-B): for each input feature only the ``P+1`` non-zero
  basis values are multiplied, against coefficient rows selected by the
  streamed index ``k`` — i.e. ``psum += sum_j vals[j] * C[k-P+j, :]``.
  On TPU the selection is expressed as a small one-hot matmul so it maps
  onto the MXU rather than a serial gather (the hardware uses an M-to-N
  mux; one-hot-matmul is its systolic equivalent).

``interpret=True`` everywhere — see ``bspline_lut.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k):
    """One (i, j, kb) grid step of the blocked matmul."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kb == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Blocked ``a @ b`` with a VMEM accumulator (weight-stationary tiles).

    Block shapes are clamped to the operand shapes so small KAN layers
    (most of Table II) don't over-allocate VMEM.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad matmul shapes {a.shape} x {b.shape}")
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    # Zero-pad to block multiples: interpret-mode Pallas fills out-of-bounds
    # block reads with NaN, which would poison the accumulator (the hardware
    # analogue is the tiler padding partial tiles with zeros — same thing the
    # cycle simulator's `imperfect tiling` accounting models).
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)
    kernel = functools.partial(_matmul_kernel, n_k=grid[2])
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[_vmem_f32((bm, bn))],
        interpret=True,
    )(a, b)
    return out[:m, :n]


def _vmem_f32(shape):
    """VMEM f32 accumulator scratch (lazy pltpu import: CPU-wheel safe)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def _sparse_kernel(vals_ref, k_ref, c_ref, o_ref, *, g, p, block_rows):
    """N:M KAN matmul tile: select N coefficient rows per feature via k.

    vals: (bm, K, P+1), k: (bm, K), c: (K, G+P, N) -> o: (bm, N).
    The inner contraction is exactly what one KAN-SAs vector-PE column
    performs over time: for every (row, feature) it multiplies the P+1
    non-zero B-spline values with the mux-selected coefficients and
    accumulates into the output partial sum.
    """
    vals = vals_ref[...]
    kk = k_ref[...]
    c = c_ref[...]
    m = g + p
    offs = jax.lax.broadcasted_iota(jnp.int32, (p + 1,), 0)
    idx = (kk[..., None] - p) + offs  # (bm, K, P+1) in [0, M-1]
    # One-hot selection (the M-to-N mux): (bm, K, P+1, M)
    sel = (idx[..., None] == jax.lax.broadcasted_iota(jnp.int32, (*idx.shape, m), idx.ndim)).astype(vals.dtype)
    # Scatter vals into dense M lanes, then contract against C on the MXU:
    # dense (bm, K, M) = sum_j vals[..., j] * sel[..., j, :]
    dense = jnp.einsum("bkj,bkjm->bkm", vals, sel)
    o_ref[...] = jnp.einsum("bkm,kmn->bn", dense, c).astype(o_ref.dtype)


def kan_matmul_sparse(
    vals: jax.Array,
    k: jax.Array,
    coeffs: jax.Array,
    g: int,
    p: int,
    *,
    block_rows: int = 128,
) -> jax.Array:
    """KAN layer GEMM from the sparse N:M view.

    Args:
        vals: ``(BS, K, P+1)`` non-zero B-spline values.
        k: ``(BS, K)`` interval indices.
        coeffs: ``(K, G+P, N)`` spline coefficients.
        g, p: layer hyperparameters.

    Returns:
        ``(BS, N)`` spline-term output, numerically equal to
        ``dense_B @ coeffs.reshape(K*(G+P), N)``.
    """
    bs, kdim, _ = vals.shape
    n = coeffs.shape[-1]
    bm = min(block_rows, bs)
    bsp = -(-bs // bm) * bm
    if bsp != bs:  # zero-pad the batch: see matmul() on interpret-mode NaN fill
        vals = jnp.pad(vals, ((0, bsp - bs), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, bsp - bs), (0, 0)), constant_values=p)
    grid = (bsp // bm,)
    kernel = functools.partial(_sparse_kernel, g=g, p=p, block_rows=bm)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kdim, p + 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((bm, kdim), lambda i: (i, 0)),
            pl.BlockSpec((kdim, g + p, n), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsp, n), jnp.float32),
        interpret=True,
    )(vals, k, coeffs)[:bs]
