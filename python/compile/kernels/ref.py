"""Pure-jnp Cox-de Boor oracle for B-spline bases.

This is the correctness reference for everything else in the repo:

* the L1 Pallas tabulation kernel (``bspline_lut.py``) is asserted against
  it in ``python/tests/test_bspline_kernel.py``;
* the quantized LUT exported to the rust engine is sampled from it;
* the rust ``bspline::reference`` module mirrors it and is cross-checked
  through golden vectors written by ``aot.py``.

Grid convention (paper Fig. 2): a uniform grid of size ``G`` covers the
input domain ``[t_P, t_{P+G}]`` and is extended by ``P`` intervals on each
side, giving ``G + 2P`` intervals, knots ``t_0 .. t_{G+2P}`` and
``N_b = G + P`` basis functions of degree ``P``.
"""

from __future__ import annotations

import jax.numpy as jnp


def make_grid(g: int, p: int, lo: float = -1.0, hi: float = 1.0) -> jnp.ndarray:
    """Extended uniform knot vector ``t_0 .. t_{G+2P}`` (Fig. 2).

    The *input domain* is ``[lo, hi] == [t_P, t_{P+G}]``; ``P`` extra
    uniform intervals are prepended/appended so that every B-spline with
    support intersecting the domain is representable.
    """
    if g < 1:
        raise ValueError(f"grid size G must be >= 1, got {g}")
    if p < 0:
        raise ValueError(f"degree P must be >= 0, got {p}")
    if not hi > lo:
        raise ValueError(f"domain must satisfy hi > lo, got [{lo}, {hi}]")
    dx = (hi - lo) / g
    return lo + dx * jnp.arange(-p, g + p + 1, dtype=jnp.float32)


def num_bases(g: int, p: int) -> int:
    """Number of degree-``P`` basis functions on the extended grid."""
    return g + p


def cox_de_boor(x: jnp.ndarray, knots: jnp.ndarray, p: int) -> jnp.ndarray:
    """Evaluate all ``G+P`` degree-``p`` B-splines at ``x`` (recursion Eqs. 2-3).

    Args:
        x: arbitrary-shaped batch of evaluation points.
        knots: extended knot vector from :func:`make_grid` (length
            ``G + 2P + 1``).
        p: spline degree.

    Returns:
        array of shape ``x.shape + (G + P,)`` with ``B_{t_i,p}(x)``.

    The implementation is the standard iterative (vectorized) form of the
    Cox-de Boor recursion: degree-0 indicators on every interval, then
    ``p`` blending passes. Division-by-zero guards follow the usual
    0/0 := 0 convention for repeated knots (never triggered on uniform
    grids but kept for generality).
    """
    x = jnp.asarray(x)
    t = jnp.asarray(knots)
    n_intervals = t.shape[0] - 1  # == G + 2P
    xe = x[..., None]

    # Degree 0: indicator of [t_i, t_{i+1}). Make the final interval
    # right-closed so x == t_last is representable.
    left = t[:-1]
    right = t[1:]
    b = jnp.where((xe >= left) & (xe < right), 1.0, 0.0)
    last = (xe >= left) & (xe == right) & (jnp.arange(n_intervals) == n_intervals - 1)
    b = jnp.where(last, 1.0, b).astype(jnp.float32)

    for d in range(1, p + 1):
        n = n_intervals - d  # number of degree-d functions
        denom_l = t[d : d + n] - t[0:n]
        denom_r = t[d + 1 : d + 1 + n] - t[1 : 1 + n]
        wl = jnp.where(denom_l > 0, (xe - t[0:n]) / jnp.where(denom_l > 0, denom_l, 1.0), 0.0)
        wr = jnp.where(
            denom_r > 0,
            (t[d + 1 : d + 1 + n] - xe) / jnp.where(denom_r > 0, denom_r, 1.0),
            0.0,
        )
        b = wl * b[..., 0:n] + wr * b[..., 1 : 1 + n]
    return b


def cardinal_bspline(u: jnp.ndarray, p: int) -> jnp.ndarray:
    """``B_{0,P}`` on integer knots ``0..P+1`` (the tabulated function).

    Support is ``[0, P+1)``; symmetric about ``(P+1)/2`` (paper Sec.
    III-B). Implemented directly from the recursion on the integer knot
    vector, which is exactly what the tabulation strategy stores.
    """
    knots = jnp.arange(0, p + 2, dtype=jnp.float32)
    u = jnp.asarray(u, dtype=jnp.float32)
    ue = u[..., None]
    b = jnp.where((ue >= knots[:-1]) & (ue < knots[1:]), 1.0, 0.0).astype(jnp.float32)
    for d in range(1, p + 1):
        n = (p + 1) - d
        wl = (ue - knots[0:n]) / d
        wr = (knots[d + 1 : d + 1 + n] - ue) / d
        b = wl * b[..., 0:n] + wr * b[..., 1 : 1 + n]
    return b[..., 0]


def interval_index(
    x: jnp.ndarray, g: int, p: int, lo: float = -1.0, hi: float = 1.0
) -> jnp.ndarray:
    """Knot-interval index ``k`` such that ``x in [t_k, t_{k+1})``.

    Inputs are clamped to the input domain ``[t_P, t_{P+G}]`` first (the
    hardware Compare unit does the same interval search over the grid
    registers), so ``k in [P, G+P-1]``.
    """
    dx = (hi - lo) / g
    u = (jnp.clip(x, lo, hi) - lo) / dx  # in [0, G]
    k = jnp.clip(jnp.floor(u).astype(jnp.int32), 0, g - 1) + p
    return k


def nonzero_bases(
    x: jnp.ndarray, g: int, p: int, lo: float = -1.0, hi: float = 1.0
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The N:M sparse view: the ``P+1`` (potentially) non-zero B-splines.

    Returns ``(values, k)`` where ``values[..., j] == B_{t_{k-P+j},P}(x)``
    for ``j = 0..P`` and ``k`` is the interval index. All other bases are
    exactly zero by local support — this is the paper's dynamic N:M
    (``N = P+1``, ``M = G+P``) density-bound block.
    """
    knots = make_grid(g, p, lo, hi)
    dense = cox_de_boor(jnp.clip(x, lo, hi), knots, p)
    k = interval_index(x, g, p, lo, hi)
    # gather the window [k-P, k] from the dense basis
    offs = jnp.arange(p + 1)
    idx = (k[..., None] - p) + offs  # in [0, G+P-1]
    vals = jnp.take_along_axis(dense, idx, axis=-1)
    return vals, k


def dense_from_sparse(
    vals: jnp.ndarray, k: jnp.ndarray, g: int, p: int
) -> jnp.ndarray:
    """Scatter the N:M sparse view back to the dense ``G+P`` basis vector."""
    m = g + p
    offs = jnp.arange(p + 1)
    idx = (k[..., None] - p) + offs
    oh = (idx[..., None] == jnp.arange(m)).astype(vals.dtype)  # (..., P+1, M)
    return jnp.einsum("...n,...nm->...m", vals, oh)
