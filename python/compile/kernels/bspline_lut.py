"""L1 Pallas kernel: non-recursive, tabulated B-spline evaluation.

This is the software twin of the paper's *B-spline unit* (Sec. III-B,
Figs. 4-5). Instead of running the Cox-de Boor recursion (Eq. 2) per
input — ~20 multipliers for a single P=3 function — the unit exploits
three properties of uniform-grid B-splines:

1. **translation/scale invariance**: every ``B_{t_k,P}`` equals the
   *cardinal* spline ``B_{0,P}`` evaluated at ``u - k`` with
   ``u = (x - t_0)/Δ`` (Eq. 4), so a single tabulated function serves all
   grids and all ``G+P`` bases;
2. **local support**: at most ``N = P+1`` bases are non-zero for any
   input, at consecutive indices ``k-P .. k``;
3. **symmetry** about ``(P+1)/2``: only half of ``B_{0,P}`` needs storing.

The hardware stores 256 rows of two packed values and mirrors the address
(``~addr``) for the upper half; here we materialize the equivalent
*full* table ``LUT[a, j] = B_{0,P}(a/(S-1) + j)`` (shape ``(S, P+1)``) —
bit-identical information, better suited to a vectorized lookup. The
bit-exact half-table + address-inversion hardware scheme is implemented
and property-tested in the rust layer (``rust/src/bspline/``); equivalence
of the two layouts is asserted in ``python/tests/test_bspline_kernel.py``.

Hardware adaptation (TPU): the LUT is a small VMEM-resident constant; the
lookup is expressed as ``one_hot(addr) @ LUT`` so the heavy lifting is an
(S x (P+1)) matmul on the MXU rather than a serial gather, and the
align/compare stage is pure VPU elementwise work. ``interpret=True``
everywhere — real-TPU lowering emits Mosaic custom-calls the CPU PJRT
plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Depth of the tabulation (the paper uses 256 = an 8-bit address).
LUT_SIZE = 256


@functools.lru_cache(maxsize=None)
def _lut_cached(p: int, size: int) -> jax.Array:
    a = jnp.arange(size, dtype=jnp.float32) / (size - 1)  # x_a in [0, 1]
    offs = jnp.arange(p + 1, dtype=jnp.float32)
    return ref.cardinal_bspline(a[:, None] + offs[None, :], p)  # (S, P+1)


def build_lut(p: int, size: int = LUT_SIZE) -> jax.Array:
    """Full float tabulation ``LUT[a, j] = B_{0,P}(a/(S-1) + j)``.

    Row ``a`` holds the values of all ``P+1`` non-zero bases for an
    aligned input ``x_a = a/(S-1)``; column ``j`` corresponds to basis
    index ``k - P + j`` (see :func:`ref.nonzero_bases`).
    """
    return _lut_cached(p, size)


def build_lut_quantized(p: int, size: int = LUT_SIZE) -> tuple[jax.Array, float]:
    """uint8 tabulation + dequantization scale (hardware ROM contents).

    The scale maximizes uint8 precision: ``max(B_{0,P})`` maps to 255.
    (The paper's Fig. 5 example values 0/32/127 correspond to a scale of
    ~192; the choice folds into the requantization constants either way.)
    """
    lut = build_lut(p, size)
    max_v = float(lut.max())
    scale = 255.0 / max_v
    q = jnp.clip(jnp.round(lut * scale), 0, 255).astype(jnp.uint8)
    return q, 1.0 / scale


def _bspline_kernel(x_ref, lut_ref, vals_ref, k_ref, *, g, p, lo, hi, lut_size, use_onehot):
    """Pallas body: align -> compare -> LUT fetch for one input tile.

    Mirrors the hardware pipeline of Fig. 5:
      Compare: interval search producing k (here: floor on the uniform grid,
               which is what the synthesized comparator tree reduces to);
      Align:   Eq. 4/5 — map x to the cardinal coordinate and quantize the
               fractional part to the LUT address;
      LUT:     fetch the P+1 non-zero basis values.
    """
    x = x_ref[...]
    dx = (hi - lo) / g
    xc = jnp.clip(x, lo, hi)
    # Compare unit: interval index within the input domain, offset by P
    # into the extended grid (k in [P, G+P-1]).
    ki = jnp.clip(jnp.floor((xc - lo) / dx), 0, g - 1).astype(jnp.int32)
    k = ki + p
    # Align unit: cardinal coordinate relative to t_0 = lo - P*dx is
    # u = (x - lo)/dx + P; the fractional part within interval k is
    # x_a = u - k in [0, 1).
    xa = (xc - lo) / dx - ki.astype(x.dtype)
    addr = jnp.clip(jnp.round(xa * (lut_size - 1)), 0, lut_size - 1).astype(jnp.int32)

    lut = lut_ref[...]  # (S, P+1), VMEM-resident
    if use_onehot:
        # MXU formulation: one-hot rows times the table.
        oh = (addr[..., None] == jax.lax.broadcasted_iota(jnp.int32, (*addr.shape, lut_size), len(addr.shape))).astype(lut.dtype)
        flat = oh.reshape(-1, lut_size) @ lut  # (B*K, P+1)
        vals = flat.reshape(*addr.shape, p + 1)
    else:
        vals = lut[addr]  # vectorized gather
    # LUT column j holds B_{0,P}(x_a + j) = B_{t_{k-j},P}(x): *descending*
    # basis index — the hardware's "reverse-packed" output (Fig. 5). Flip to
    # the ascending k-P..k order used by the SA coefficient mux and the
    # oracle.
    vals = vals[..., ::-1]
    vals_ref[...] = vals.astype(vals_ref.dtype)
    k_ref[...] = k


def bspline_activations(
    x: jax.Array,
    g: int,
    p: int,
    lo: float = -1.0,
    hi: float = 1.0,
    *,
    lut_size: int = LUT_SIZE,
    use_onehot: bool = True,
    block_rows: int = 128,
    lut: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Evaluate the N:M sparse B-spline view of ``x`` via the LUT kernel.

    Args:
        x: input activations, shape ``(BS, K)``.
        g, p: grid size and spline degree (KAN layer hyperparameters).
        lo, hi: input domain ``[t_P, t_{P+G}]``.
        lut_size: tabulation depth (256 in the paper's 8-bit unit).
        use_onehot: one-hot-matmul (MXU) vs gather formulation.
        block_rows: batch tile per grid step (VMEM sizing knob).
        lut: optionally pass the tabulation as an explicit operand (the AOT
            export does this so the table becomes a named HLO parameter fed
            by the rust runtime instead of a trace-hoisted constant).

    Returns:
        ``(vals, k)`` with ``vals: (BS, K, P+1)`` float32 and
        ``k: (BS, K)`` int32 — exactly the signal pair the hardware
        B-spline unit streams into its row of N:M PEs (Fig. 6).
    """
    if x.ndim != 2:
        raise ValueError(f"expected (BS, K) input, got shape {x.shape}")
    if p < 1:
        # P=0 is a discontinuous indicator: address rounding at the interval
        # boundary cannot represent it. The paper's workloads use P in
        # {1,2,3} (Table II); the Cox-de Boor oracle still covers P=0.
        raise ValueError(f"tabulated unit requires degree P >= 1, got {p}")
    bs, kdim = x.shape
    if lut is None:
        lut = build_lut(p, lut_size)
    if lut.shape != (lut_size, p + 1):
        raise ValueError(f"LUT shape {lut.shape} != {(lut_size, p + 1)}")
    rows = min(block_rows, bs)
    grid = (pl.cdiv(bs, rows),)
    kernel = functools.partial(
        _bspline_kernel,
        g=g, p=p, lo=lo, hi=hi, lut_size=lut_size, use_onehot=use_onehot,
    )
    vals, k = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, kdim), lambda i: (i, 0)),
            pl.BlockSpec((lut_size, p + 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows, kdim, p + 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((rows, kdim), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs, kdim, p + 1), jnp.float32),
            jax.ShapeDtypeStruct((bs, kdim), jnp.int32),
        ],
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(x, lut)
    return vals, k


def bspline_dense(
    x: jax.Array,
    g: int,
    p: int,
    lo: float = -1.0,
    hi: float = 1.0,
    **kw,
) -> jax.Array:
    """Dense ``(BS, K*(G+P))`` B-spline activation matrix (paper Fig. 1c).

    This is the matrix **B** a conventional SA consumes; KAN-SAs never
    materializes it (the sparse ``(vals, k)`` pair goes straight to the
    vector PEs), but the GEMM formulation needs it and it doubles as a
    second oracle for the sparse path.
    """
    bs, kdim = x.shape
    vals, k = bspline_activations(x, g, p, lo, hi, **kw)
    dense = ref.dense_from_sparse(vals, k, g, p)  # (BS, K, G+P)
    return dense.reshape(bs, kdim * (g + p))
