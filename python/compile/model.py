"""L2: KAN models in JAX, built on the L1 Pallas kernels.

A KAN layer (paper Eq. 1) computes, per output unit,

    KANLayer(x) = sum_i w_i phi_i(x_i) + w_b * b(x)

where each ``phi_i`` is a learnable spline ``phi(x) = sum_j c_j B_j(x)``
in the (G+P)-function B-spline basis, and the second term is an ordinary
MLP path with a fixed non-linearity ``b`` (the paper replaces the usual
SiLU with ReLU; we follow it). At inference the ``w_i`` scales are
absorbed into the coefficients, so the layer is exactly:

    y = B(x) @ C + relu(x) @ Wb            (Fig. 1c)

with ``B(x)`` the ``(BS, K*(G+P))`` B-spline activation matrix produced
by the L1 tabulation kernel and ``C`` the ``(K*(G+P), N)`` coefficient
matrix. This file provides the layer, whole-model forward passes for the
benchmark applications, parameter init, and a small self-contained Adam
trainer (optax is not available in the build image).

Between layers the pre-activations are squashed with ``tanh`` so they
land in the spline input domain ``[-1, 1]`` — the standard efficient-KAN
style domain-keeping trick; the hardware Compare unit clamps anything
that still escapes, and the JAX path clips identically, so the two
implementations agree bit-for-bit after quantization.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .kernels import bspline_lut, kan_gemm, ref


class KanLayerSpec(NamedTuple):
    """Static hyperparameters of one KAN layer."""

    in_dim: int
    out_dim: int
    grid: int = 5       # G
    degree: int = 3     # P
    lo: float = -1.0    # t_P
    hi: float = 1.0     # t_{P+G}

    @property
    def num_bases(self) -> int:
        return self.grid + self.degree

    @property
    def coeff_shape(self) -> tuple[int, int, int]:
        return (self.in_dim, self.num_bases, self.out_dim)


class KanModelSpec(NamedTuple):
    """A stack of KAN layers: dims [d0, d1, ..., dL], shared G/P."""

    dims: tuple[int, ...]
    grid: int = 5
    degree: int = 3
    name: str = "kan"

    @property
    def layers(self) -> list[KanLayerSpec]:
        return [
            KanLayerSpec(self.dims[i], self.dims[i + 1], self.grid, self.degree)
            for i in range(len(self.dims) - 1)
        ]


def init_layer(key: jax.Array, spec: KanLayerSpec) -> dict[str, jax.Array]:
    """Initialize one layer: spline coefficients + base (ReLU-path) weights.

    Coefficients start as small noise (so the splines begin near zero and
    the ReLU base path dominates early training — the init used by the
    reference KAN implementations), base weights use Kaiming-uniform.
    """
    kc, kb = jax.random.split(key)
    coeff = 0.1 * jax.random.normal(kc, spec.coeff_shape, dtype=jnp.float32) / math.sqrt(spec.in_dim)
    bound = math.sqrt(6.0 / spec.in_dim)
    base = jax.random.uniform(kb, (spec.in_dim, spec.out_dim), jnp.float32, -bound, bound)
    return {"coeff": coeff, "base": base}


def init_model(key: jax.Array, spec: KanModelSpec) -> list[dict[str, jax.Array]]:
    keys = jax.random.split(key, len(spec.layers))
    return [init_layer(k, layer) for k, layer in zip(keys, spec.layers)]


def kan_layer(
    params: dict[str, jax.Array],
    x: jax.Array,
    spec: KanLayerSpec,
    *,
    use_pallas: bool = True,
    lut: jax.Array | None = None,
) -> jax.Array:
    """Forward one KAN layer: spline term + ReLU base term (Eq. 1).

    ``use_pallas=True`` routes through the L1 kernels (tabulated B-spline
    unit + blocked GEMM); ``False`` uses the Cox-de Boor oracle — the pair
    is the layer-level correctness check in the test suite, and the oracle
    path is what training differentiates through (the LUT has no useful
    gradient in the tabulated direction).
    """
    if use_pallas:
        vals, k = bspline_lut.bspline_activations(
            x, spec.grid, spec.degree, spec.lo, spec.hi, lut=lut
        )
        spline = kan_gemm.kan_matmul_sparse(vals, k, params["coeff"], spec.grid, spec.degree)
    else:
        knots = ref.make_grid(spec.grid, spec.degree, spec.lo, spec.hi)
        b = ref.cox_de_boor(jnp.clip(x, spec.lo, spec.hi), knots, spec.degree)
        spline = jnp.einsum("bkm,kmn->bn", b, params["coeff"])
    base = jax.nn.relu(x) @ params["base"]
    return spline + base


def kan_forward(
    params: Sequence[dict[str, jax.Array]],
    x: jax.Array,
    spec: KanModelSpec,
    *,
    use_pallas: bool = True,
    luts: Sequence[jax.Array] | None = None,
) -> jax.Array:
    """Whole-model forward. Hidden pre-activations are hard-clipped into
    the spline domain; the final layer output is returned raw (logits)."""
    h = x
    for i, layer in enumerate(spec.layers):
        lut = None if luts is None else luts[i]
        h = kan_layer(params[i], h, layer, use_pallas=use_pallas, lut=lut)
        if i + 1 < len(spec.layers):
            h = jnp.clip(h, layer.lo, layer.hi)
    return h


# ---------------------------------------------------------------------------
# Benchmark model zoo (paper Table II shapes that we actually train/run).
# ---------------------------------------------------------------------------

def mnist_kan() -> KanModelSpec:
    """MNIST-KAN [784, 64, 10], G=10, P=3 (paper Sec. V-C / [28])."""
    return KanModelSpec(dims=(784, 64, 10), grid=10, degree=3, name="mnist_kan")


def quickstart_kan() -> KanModelSpec:
    """Tiny [4, 8, 3] KAN used by the quickstart example and smoke tests."""
    return KanModelSpec(dims=(4, 8, 3), grid=5, degree=3, name="quickstart_kan")


def catch22_kan(num_classes: int = 10) -> KanModelSpec:
    """Catch22-KAN [22, X] single layer, G=3, P=3 (paper Table II / [26])."""
    return KanModelSpec(dims=(22, num_classes), grid=3, degree=3, name="catch22_kan")


# ---------------------------------------------------------------------------
# Self-contained Adam (optax is unavailable offline).
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adam_init(params: Any) -> AdamState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamState(jnp.zeros((), jnp.int32), zeros, jax.tree.map(jnp.zeros_like, params))


def adam_update(
    grads: Any,
    state: AdamState,
    params: Any,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> tuple[Any, AdamState]:
    step = state.step + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t
    new_params = jax.tree.map(
        lambda p, m, v: p - lr * (m / bc1 / (jnp.sqrt(v / bc2) + eps) + weight_decay * p),
        params, mu, nu,
    )
    return new_params, AdamState(step, mu, nu)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - ll)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
