"""Integer-only post-training quantization of KAN models (paper Sec. V).

The paper validates an integer-only implementation "quantized as proposed
by [18]" (Jacob et al.) against a software baseline, reporting <1%
accuracy drop (MNIST-KAN 96.58% -> 96.0%). This module is the *bit-exact
software specification* of that integer pipeline; ``rust/src/kan`` and
``rust/src/bspline`` implement the very same arithmetic and are checked
against golden vectors exported from here.

Fixed-point conventions
-----------------------

* **Activations**: uint8 with zero-point 128 and scale 1/128 over the
  spline domain, i.e. ``x_q = clamp(round(x * 128) + 128, 0, 255)``.
  With this choice the B-spline unit's Align arithmetic (paper Eq. 5)
  becomes exact integer math::

      u    = (x - lo)/dx = x01 * G            (x01 = x_q / 256)
      ki   = (x_q * G) >> 8                    # Compare unit: interval in [0, G-1]
      addr = x_q * G - (ki << 8)               # Align unit: frac * 256, in [0, 255]
      k    = ki + P                             # index streamed to the PEs

  (The paper's Eq. 5 has the same shape with the constant (G+2P) because
  its ``x_q`` spans the *extended* grid; ours spans the input domain.)
* **LUT**: 256 rows, ``P+1`` uint8 values per row, row ``a`` sampled at
  ``x_a = a / 256``; column ``j`` already in *ascending* basis order
  (``k - P + j``), absorbing the hardware's reverse-packing. Scale
  ``s_B = max(B_{0,P}) / 255`` maps 255 to the spline's peak.
* **Weights**: int8, symmetric per-tensor (``s_c``, ``s_w``).
* **Accumulation**: int32 (uint8 x int8 products), as in the PE datapath
  (8-bit inputs, 32-bit output — Table I).
* **Requantization** (between layers): the float op is
  ``clip(spline + base, -1, 1)`` followed by activation quantization;
  in fixed point::

      t   = acc_spline * m1 + acc_base * m2            # int64
      y_q = clamp(128 + (t + 2^(SHIFT-1)) >> SHIFT, 0, 255)

  with ``m1 = round(s_B * s_c * 128 * 2^SHIFT)`` etc. — the standard
  integer-only requantization of [18].
* **Logits**: the last layer keeps the int64 ``t`` (monotone in the float
  logits), so classification is integer-only end to end.
"""

from __future__ import annotations

import numpy as np

from . import model
from .kernels import ref

LUT_SIZE = 256
SHIFT = 24
ZP = 128  # activation zero point


def quantize_activations(x: np.ndarray) -> np.ndarray:
    """Float spline-domain activations -> uint8 (zp=128, scale=1/128)."""
    return np.clip(np.round(x * 128.0) + ZP, 0, 255).astype(np.uint8)


def dequantize_activations(x_q: np.ndarray) -> np.ndarray:
    return (x_q.astype(np.float32) - ZP) / 128.0


def quantize_symmetric(w: np.ndarray) -> tuple[np.ndarray, float]:
    """Float tensor -> (int8, scale), symmetric per-tensor."""
    amax = float(np.abs(w).max())
    scale = amax / 127.0 if amax > 0 else 1.0
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale


def quantize_symmetric_int4(w: np.ndarray) -> tuple[np.ndarray, float]:
    """Float tensor -> (int4-valued int8, scale), symmetric per-tensor.

    Values land in the symmetric int4 range [-7, 7]; storage here stays
    int8 — nibble packing happens at export (``aot.pack_int4``). The
    coarser scale is absorbed into ``s_c``/``s_w``, so the requant
    multiplier formulas are unchanged.
    """
    amax = float(np.abs(w).max())
    scale = amax / 7.0 if amax > 0 else 1.0
    q = np.clip(np.round(w / scale), -7, 7).astype(np.int8)
    return q, scale


def int4_error(w: np.ndarray) -> float:
    """Normalized RMS reconstruction error of native int4 quantization:
    ``sqrt(sum((w - s*q)^2) / sum(w^2))`` — the per-layer metric the
    ``--int4-budget`` demotion policy thresholds against (mirrors
    ``QuantizedModel::with_precision_budget`` on the rust side)."""
    q, s = quantize_symmetric_int4(w)
    e = w.astype(np.float64) - q.astype(np.float64) * s
    denom = float(np.sum(w.astype(np.float64) ** 2))
    if denom <= 0.0:
        return 0.0
    return float(np.sqrt(np.sum(e * e) / denom))


def build_lut_q(p: int) -> tuple[np.ndarray, float]:
    """Quantized tabulation: ``LUT[a, j] = round(B_{0,P}(a/256 + P - j)/s_B)``.

    Column ``j`` corresponds to basis index ``k - P + j`` (ascending), i.e.
    the reverse-packed hardware order is already resolved here. Returns
    (uint8 array of shape (256, P+1), scale ``s_B``).
    """
    a = np.arange(LUT_SIZE, dtype=np.float64) / LUT_SIZE
    offs = np.arange(p, -1, -1, dtype=np.float64)  # P - j
    vals = np.asarray(ref.cardinal_bspline((a[:, None] + offs[None, :]).astype(np.float32), p))
    max_b = float(np.asarray(ref.cardinal_bspline(np.float32((p + 1) / 2.0), p)))
    s_b = max_b / 255.0
    lut = np.clip(np.round(vals / s_b), 0, 255).astype(np.uint8)
    return lut, s_b


def bspline_unit_q(x_q: np.ndarray, lut: np.ndarray, g: int, p: int) -> tuple[np.ndarray, np.ndarray]:
    """Integer B-spline unit: (x_q uint8) -> (vals uint8 (..., P+1), k int32).

    Pure integer arithmetic, mirrored exactly by ``rust/src/bspline/unit.rs``.
    """
    xq = x_q.astype(np.int64)
    ki = (xq * g) >> 8                      # Compare: interval in [0, G-1]
    addr = (xq * g - (ki << 8)).astype(np.int64)  # Align: in [0, 255]
    vals = lut[addr]                        # LUT fetch: (..., P+1) uint8
    k = (ki + p).astype(np.int32)
    return vals, k


class QuantizedLayer:
    """Integer-only KAN layer: LUT + int8 coeff/base + requant constants."""

    def __init__(self, params: dict, spec: model.KanLayerSpec, precision: str = "int8"):
        if precision not in ("int8", "int4"):
            raise ValueError(f"unknown precision {precision!r} (want int8|int4)")
        self.spec = spec
        self.precision = precision
        self.lut, self.s_b = build_lut_q(spec.degree)
        coeff = np.asarray(params["coeff"], dtype=np.float32)  # (K, M, N)
        base = np.asarray(params["base"], dtype=np.float32)    # (K, N)
        quant_w = quantize_symmetric_int4 if precision == "int4" else quantize_symmetric
        self.coeff_q, self.s_c = quant_w(coeff)
        self.base_q, self.s_w = quant_w(base)
        # requant multipliers: float-scale * 128 (next-layer act scale) * 2^SHIFT
        self.m1 = int(round(self.s_b * self.s_c * 128.0 * (1 << SHIFT)))
        self.m2 = int(round((1.0 / 128.0) * self.s_w * 128.0 * (1 << SHIFT)))
        # float dequant scales for logits
        self.s1 = self.s_b * self.s_c
        self.s2 = (1.0 / 128.0) * self.s_w

    def forward_int(self, x_q: np.ndarray) -> np.ndarray:
        """uint8 (BS, K) -> int64 pre-requant accumulator t (BS, N)."""
        g, p = self.spec.grid, self.spec.degree
        vals, k = bspline_unit_q(x_q, self.lut, g, p)  # (BS,K,P+1), (BS,K)
        bs, kdim = x_q.shape
        n = self.spec.out_dim
        # N:M spline GEMM: acc[b,n] = sum_{i,j} vals[b,i,j]*coeff[i, k-P+j, n]
        offs = np.arange(p + 1)
        idx = (k[..., None] - p) + offs                 # (BS, K, P+1)
        # gather coefficient rows: (BS, K, P+1, N)
        cg = self.coeff_q[np.arange(kdim)[None, :, None], idx]
        acc_spline = np.einsum(
            "bkj,bkjn->bn", vals.astype(np.int64), cg.astype(np.int64)
        )
        # base path: integer ReLU around the zero point
        r_q = np.maximum(x_q.astype(np.int64), ZP) - ZP  # [0, 127], scale 1/128
        acc_base = r_q @ self.base_q.astype(np.int64)
        return acc_spline * self.m1 + acc_base * self.m2  # int64

    def requantize(self, t: np.ndarray) -> np.ndarray:
        """int64 t -> next-layer uint8 activations (rounding shift + clamp)."""
        y = (t + (1 << (SHIFT - 1))) >> SHIFT
        return np.clip(y + ZP, 0, 255).astype(np.uint8)

    def dequantize_logits(self, t: np.ndarray) -> np.ndarray:
        """int64 t -> float logits (for reporting; argmax(t) is identical)."""
        return t.astype(np.float64) / (128.0 * (1 << SHIFT))


class QuantizedModel:
    """Integer-only KAN inference — the software twin of the rust engine."""

    def __init__(
        self,
        params: list[dict],
        spec: model.KanModelSpec,
        precisions: list[str] | None = None,
    ):
        self.spec = spec
        if precisions is None:
            precisions = ["int8"] * len(spec.layers)
        if len(precisions) != len(spec.layers):
            raise ValueError(f"{len(precisions)} precisions for {len(spec.layers)} layers")
        self.layers = [
            QuantizedLayer(p, s, prec)
            for p, s, prec in zip(params, spec.layers, precisions)
        ]

    def forward_int(self, x: np.ndarray) -> np.ndarray:
        """Float inputs -> int64 logits-accumulator (BS, out_dim)."""
        x_q = quantize_activations(np.asarray(x, dtype=np.float32))
        return self.forward_from_q(x_q)

    def forward_from_q(self, x_q: np.ndarray) -> np.ndarray:
        t = None
        for i, layer in enumerate(self.layers):
            t = layer.forward_int(x_q)
            if i + 1 < len(self.layers):
                x_q = layer.requantize(t)
        return t

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.argmax(self.forward_int(x), axis=-1).astype(np.int32)

    def accuracy(self, x: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(x) == y))
