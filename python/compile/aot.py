"""AOT artifact builder: train -> quantize -> export (the `make artifacts` entry).

Produces everything the rust layer consumes, under ``artifacts/``:

* ``<model>_params.npz``      — trained float parameters (build cache).
* ``<model>.kanq``            — quantized model for the bit-exact integer
                                engine (``rust/src/kan``): LUTs, int8
                                coefficients/base weights, requantization
                                constants. Custom binary format, below.
* ``<model>_golden.kgld``     — golden vectors (inputs + expected
                                intermediate and final integer tensors)
                                replayed by rust tests for exact equality.
* ``<model>_b<BS>.hlo.txt``   — the fp32 forward pass (L2 jax calling the
                                L1 Pallas kernels) lowered to **HLO text**
                                for the PJRT runtime. Text, not
                                ``.serialize()``: jax >= 0.5 emits protos
                                with 64-bit instruction ids that
                                xla_extension 0.5.1 rejects; the text
                                parser reassigns ids and round-trips.
* ``train_metrics.json`` / ``quant_metrics.json`` — accuracy bookkeeping
                                for EXPERIMENTS.md.

Binary container format (shared by .kanq and .kgld): the file starts with
an 8-byte magic, a little-endian u32 JSON-header length, the UTF-8 JSON
header, then raw little-endian tensor blobs. The header's ``tensors``
table maps names to (dtype, shape, offset, nbytes) with offsets relative
to the end of the header. ``rust/src/util/container.rs`` is the reader.
"""

from __future__ import annotations

import argparse
import json
import struct
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, quantize, train
from .kernels import bspline_lut

ROOT = Path(__file__).resolve().parents[2]
ARTIFACTS = ROOT / "artifacts"

MAGIC_KANQ = b"KANQ0001"
MAGIC_GOLD = b"KGLD0001"
MAGIC_WTS = b"KWTS0001"


# ---------------------------------------------------------------------------
# Binary container writer
# ---------------------------------------------------------------------------

def write_container(path: Path, magic: bytes, meta: dict, tensors: dict[str, np.ndarray]) -> None:
    assert len(magic) == 8
    blobs = []
    table = {}
    off = 0
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        raw = arr.tobytes()
        table[name] = {
            "dtype": arr.dtype.name,
            "shape": list(arr.shape),
            "offset": off,
            "nbytes": len(raw),
        }
        blobs.append(raw)
        off += len(raw)
    header = dict(meta)
    header["tensors"] = table
    hraw = json.dumps(header).encode("utf-8")
    with open(path, "wb") as f:
        f.write(magic)
        f.write(struct.pack("<I", len(hraw)))
        f.write(hraw)
        for b in blobs:
            f.write(b)


# ---------------------------------------------------------------------------
# Quantized model + golden export
# ---------------------------------------------------------------------------

def pack_int4(a: np.ndarray) -> np.ndarray:
    """Two's-complement int4 nibbles, two per byte along the last axis.

    Element ``2i`` is the low nibble of byte ``i``, element ``2i+1`` the
    high nibble; an odd last axis leaves the final high nibble zero.
    Mirrors ``pack_i4``/``unpack_i4`` in ``rust/src/quant``.
    """
    a = np.asarray(a, dtype=np.int8)
    if a.shape[-1] % 2:
        a = np.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, 1)])
    nib = a.astype(np.uint8) & 0x0F
    return (nib[..., 0::2] | (nib[..., 1::2] << 4)).astype(np.uint8)


def export_kanq(qm: quantize.QuantizedModel, path: Path) -> None:
    spec = qm.spec
    meta = {
        "name": spec.name,
        "dims": list(spec.dims),
        "grid": spec.grid,
        "degree": spec.degree,
        "shift": quantize.SHIFT,
        "zero_point": quantize.ZP,
        "lut_size": quantize.LUT_SIZE,
        "layers": [],
    }
    tensors = {}
    for i, layer in enumerate(qm.layers):
        lmeta = {
            "in_dim": layer.spec.in_dim,
            "out_dim": layer.spec.out_dim,
            "grid": layer.spec.grid,
            "degree": layer.spec.degree,
            "s_b": layer.s_b,
            "s_c": layer.s_c,
            "s_w": layer.s_w,
            "m1": layer.m1,
            "m2": layer.m2,
            "s1": layer.s1,
            "s2": layer.s2,
        }
        # absent "precision" means int8 — readers of pre-int4 artifacts
        # and this writer stay mutually compatible
        if layer.precision != "int8":
            lmeta["precision"] = layer.precision
        meta["layers"].append(lmeta)
        tensors[f"l{i}.lut"] = layer.lut            # (256, P+1) u8
        if layer.precision == "int4":
            tensors[f"l{i}.coeff4"] = pack_int4(layer.coeff_q)  # (K, M, RB) u8
            tensors[f"l{i}.base4"] = pack_int4(layer.base_q)    # (K, RB)    u8
        else:
            tensors[f"l{i}.coeff"] = layer.coeff_q  # (K, M, N)  i8
            tensors[f"l{i}.base"] = layer.base_q    # (K, N)     i8
    write_container(path, MAGIC_KANQ, meta, tensors)


def export_golden(
    qm: quantize.QuantizedModel, x: np.ndarray, y: np.ndarray, path: Path
) -> None:
    """Golden vectors: inputs, layer-0 unit outputs, final accumulators."""
    spec = qm.spec
    x_q = quantize.quantize_activations(np.asarray(x, dtype=np.float32))
    l0 = qm.layers[0]
    vals0, k0 = quantize.bspline_unit_q(x_q, l0.lut, l0.spec.grid, l0.spec.degree)
    # per-layer activation trace
    acts = [x_q]
    t = None
    cur = x_q
    for i, layer in enumerate(qm.layers):
        t = layer.forward_int(cur)
        if i + 1 < len(qm.layers):
            cur = layer.requantize(t)
            acts.append(cur)
    tensors = {
        "x_q": x_q,
        "labels": y.astype(np.int32),
        "l0.vals": vals0,
        "l0.k": k0,
        "t_final": t.astype(np.int64),
        "pred": np.argmax(t, axis=-1).astype(np.int32),
    }
    for i, a in enumerate(acts[1:], start=1):
        tensors[f"act{i}"] = a
    write_container(
        path,
        MAGIC_GOLD,
        {"name": spec.name, "batch": int(x_q.shape[0]), "dims": list(spec.dims)},
        tensors,
    )


# ---------------------------------------------------------------------------
# HLO text export (the jax -> rust interchange)
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_hlo(
    params: list[dict], spec: model.KanModelSpec, batch_sizes: tuple[int, ...], outdir: Path
) -> list[str]:
    """Lower the fp32 forward (Pallas kernels included, interpret=True) to
    HLO text, one module per static batch size.

    Weights (and the per-layer B-spline LUTs) are *explicit leading
    parameters* in a recorded order, fed once as literals by the rust
    runtime — jax would otherwise hoist the closed-over arrays into
    parameters in an order we don't control. The order is written to
    ``<model>.kwts`` alongside the fp32 tensors.
    """
    written = []
    # Flat, explicitly ordered weight list: per layer [coeff, base, lut].
    names: list[str] = []
    flats: list[jnp.ndarray] = []
    for i, (layer_params, layer_spec) in enumerate(zip(params, spec.layers)):
        names.append(f"l{i}.coeff")
        flats.append(jnp.asarray(layer_params["coeff"], jnp.float32))
        names.append(f"l{i}.base")
        flats.append(jnp.asarray(layer_params["base"], jnp.float32))
        names.append(f"l{i}.lut")
        flats.append(bspline_lut.build_lut(layer_spec.degree))

    def fwd(*args):
        *wts, x = args
        ps = [
            {"coeff": wts[3 * i], "base": wts[3 * i + 1]}
            for i in range(len(spec.layers))
        ]
        luts = [wts[3 * i + 2] for i in range(len(spec.layers))]
        return (model.kan_forward(ps, x, spec, use_pallas=True, luts=luts),)

    for bs in batch_sizes:
        arg_specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flats]
        arg_specs.append(jax.ShapeDtypeStruct((bs, spec.dims[0]), jnp.float32))
        lowered = jax.jit(fwd).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = outdir / f"{spec.name}_b{bs}.hlo.txt"
        path.write_text(text)
        written.append(path.name)

    write_container(
        outdir / f"{spec.name}.kwts",
        MAGIC_WTS,
        {"name": spec.name, "order": names, "batch_sizes": list(batch_sizes)},
        {n: np.asarray(a) for n, a in zip(names, flats)},
    )
    return written


# ---------------------------------------------------------------------------
# Orchestration
# ---------------------------------------------------------------------------

def choose_precisions(params: list[dict], budget: float | None) -> list[str] | None:
    """Per-layer precision from an int4 quantization-error budget: a layer
    whose native-int4 normalized RMS error (worst of coeff/base) stays
    within the budget exports packed int4; the rest stay int8. ``None``
    budget (the default) keeps every layer int8."""
    if budget is None:
        return None
    precs = []
    for p in params:
        err = max(
            quantize.int4_error(np.asarray(p["coeff"], dtype=np.float32)),
            quantize.int4_error(np.asarray(p["base"], dtype=np.float32)),
        )
        precs.append("int4" if err <= budget else "int8")
    return precs


def build_model(name: str, retrain: bool, quant_metrics: dict, int4_budget: float | None = None) -> None:
    if name == "quickstart_kan":
        spec = model.quickstart_kan()
        datasets = train.blob_datasets()
        trainer = train.train_quickstart
        batch_sizes = (1, 32)
    elif name == "mnist_kan":
        spec = model.mnist_kan()
        datasets = train.digit_datasets()
        trainer = train.train_mnist_kan
        batch_sizes = (1, 32, 128)
    elif name == "catch22_kan":
        spec = model.catch22_kan(10)
        datasets = train.timeseries_datasets()
        trainer = train.train_catch22
        batch_sizes = (1, 32)
    else:
        raise ValueError(f"unknown model {name}")

    params_path = ARTIFACTS / f"{spec.name}_params.npz"
    if params_path.exists() and not retrain:
        params = train.load_params(params_path)
        metrics = {"name": spec.name, "cached": True}
    else:
        params, metrics = trainer()
        train.save_params(params, params_path)

    xtr, ytr, xte, yte = datasets
    # fp32 reference accuracy (oracle path)
    logits = model.kan_forward(params, jnp.asarray(xte), spec, use_pallas=False)
    fp32_acc = float(model.accuracy(logits, jnp.asarray(yte)))

    precisions = choose_precisions(params, int4_budget)
    qm = quantize.QuantizedModel(params, spec, precisions)
    int8_acc = qm.accuracy(xte, yte)
    export_kanq(qm, ARTIFACTS / f"{spec.name}.kanq")
    export_golden(qm, xte[:64], yte[:64], ARTIFACTS / f"{spec.name}_golden.kgld")
    hlos = export_hlo(params, spec, batch_sizes, ARTIFACTS)

    layer_precs = [layer.precision for layer in qm.layers]
    quant_metrics[spec.name] = {
        "fp32_test_acc": fp32_acc,
        "int8_test_acc": int8_acc,
        "acc_drop": fp32_acc - int8_acc,
        "precisions": layer_precs,
        "hlo_modules": hlos,
        "train": metrics if metrics.get("cached") else {k: v for k, v in metrics.items() if k != "history"},
    }
    print(
        f"[{spec.name}] fp32 {fp32_acc:.4f}  quant {int8_acc:.4f}  "
        f"drop {fp32_acc - int8_acc:.4f}  precisions {layer_precs}  hlo {hlos}"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="unused (kept for Makefile compat)")
    ap.add_argument("--retrain", action="store_true", help="ignore cached params")
    ap.add_argument(
        "--models", nargs="*", default=["quickstart_kan", "mnist_kan", "catch22_kan"],
        help="which models to build",
    )
    ap.add_argument(
        "--int4-budget", type=float, default=None, metavar="RMS",
        help="per-layer normalized-RMS error budget for native int4 "
        "quantization; layers within budget export packed int4 nibbles "
        "(default: every layer int8)",
    )
    args = ap.parse_args()
    ARTIFACTS.mkdir(exist_ok=True)
    quant_metrics = {}
    for name in args.models:
        build_model(name, args.retrain, quant_metrics, args.int4_budget)
    path = ARTIFACTS / "quant_metrics.json"
    existing = json.loads(path.read_text()) if path.exists() else {}
    existing.update(quant_metrics)
    path.write_text(json.dumps(existing, indent=2))
    # marker consumed by the Makefile's up-to-date check
    (ARTIFACTS / ".stamp").write_text("ok\n")
    print(f"artifacts written to {ARTIFACTS}")


if __name__ == "__main__":
    main()
